"""Batched serving example: chunked-prefill continuous batching with
mixed prompt lengths and request arrival between ticks, on any assigned
architecture (including the hybrid/SSM ones, whose decode uses recurrent
state).  Admission costs ceil(S/chunk) jitted steps per prompt; the
decode tick is one jitted step for all slots.

    PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-7b
"""
import argparse
import math

import jax

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServingEngine, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ALL_ARCHS), default="rwkv6-7b")
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--chunk", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(params, cfg, slots=args.slots, cache_len=96,
                           chunk=args.chunk)

    # first wave
    for i in range(4):
        engine.submit(Request(i, [1 + i, 2, 3], max_new=6))
    ticks = 0
    while engine.tick():
        ticks += 1
        if ticks == 3:   # late arrivals join running batch
            engine.submit(Request(100, [7, 8, 9, 10], max_new=5))
            engine.submit(Request(101, [7, 8, 9, 10], max_new=5))
    done = sorted(engine.finished, key=lambda r: r.req_id)
    st = engine.stats
    print(f"{cfg.name}: {len(done)} requests over {ticks} engine ticks")
    print(f"  {st['prefill_calls']} chunked-prefill steps (chunk="
          f"{engine.chunk}) + {st['decode_calls']} decode steps for "
          f"{st['admitted']} admissions")
    for r in done:
        print(f"  req{r.req_id:3d} prompt={r.prompt} -> {r.generated}")
    # admission cost is ceil(S/chunk) steps per prompt, never S
    expected = sum(math.ceil(len(r.prompt) / engine.chunk) for r in done)
    assert st["prefill_calls"] == expected, (st["prefill_calls"], expected)
    # same-prompt requests must decode identically (slot isolation)
    assert done[-1].generated == done[-2].generated
    ref = generate(params, cfg,
                   jax.numpy.asarray([[7, 8, 9, 10]], jax.numpy.int32),
                   max_new=5)[0, 4:].tolist()
    assert done[-1].generated == ref, (done[-1].generated, ref)
    print("late-arrival decode == fresh generate() ✓")


if __name__ == "__main__":
    main()
