"""Batched serving example: chunked-prefill continuous batching with
mixed prompt lengths and request arrival between ticks, on any assigned
architecture (including the hybrid/SSM ones, whose decode uses recurrent
state).  Admission costs ceil(S/chunk) jitted steps per prompt; the
decode tick is one jitted step for all slots.

By default the KV cache is **paged** (``--no-paged`` for the dense
per-slot rings): each request takes ceil((prompt + max_new) / page_size)
pages from a shared ``--num-blocks`` pool through a block table, so
short and long requests stop sharing one worst-case cache_len and the
queue backpressures (instead of crashing) when the pool is full.  The
example asserts paged and dense decode are token-identical (and, with
``--kernel``, that the fused Pallas paged-decode kernel matches the
scan path too).  ``--temperature``/``--top-p``/``--top-k``/
``--rep-penalty`` exercise the in-jit per-slot sampler instead.

    PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-7b
"""
import argparse
import math

import jax

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServingEngine, generate


def serve(params, cfg, args, paged: bool, use_kernel: bool = False,
          share: bool = True):
    engine = ServingEngine(params, cfg, slots=args.slots, cache_len=96,
                           chunk=args.chunk, paged=paged,
                           page_size=args.page_size,
                           num_blocks=args.num_blocks or None,
                           use_kernel=use_kernel, share_prefix=share)
    sample_kw = dict(temperature=args.temperature, top_p=args.top_p,
                     top_k=args.top_k, rep_penalty=args.rep_penalty)
    # first wave
    for i in range(4):
        engine.submit(Request(i, [1 + i, 2, 3], max_new=6, **sample_kw))
    ticks = 0
    while engine.tick():
        ticks += 1
        if ticks == 3:   # late arrivals join running batch
            engine.submit(Request(100, [7, 8, 9, 10], max_new=5, **sample_kw))
            engine.submit(Request(101, [7, 8, 9, 10], max_new=5, **sample_kw))
    return engine, ticks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ALL_ARCHS), default="rwkv6-7b")
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--paged", dest="paged", action="store_true",
                    default=True, help="block-table KV cache (default)")
    ap.add_argument("--no-paged", dest="paged", action="store_false",
                    help="dense per-slot ring caches only")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="0 = same memory as the dense cache")
    ap.add_argument("--kernel", action="store_true",
                    help="decode through the fused Pallas paged-attention "
                         "kernel (paged mode only)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy)")
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=0,
                    help="0 = no top-k cut")
    ap.add_argument("--rep-penalty", type=float, default=1.0,
                    help="1.0 = no repetition penalty")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine, ticks = serve(params, cfg, args, paged=args.paged,
                          use_kernel=args.kernel)
    done = sorted(engine.finished, key=lambda r: r.req_id)
    st = engine.stats
    mode = (f"paged pool {engine.num_blocks}x{engine.page_size}"
            if engine.paged else "dense rings")
    print(f"{cfg.name}: {len(done)} requests over {ticks} engine ticks "
          f"({mode})")
    print(f"  {st['prefill_calls']} chunked-prefill steps (chunk="
          f"{engine.chunk}) + {st['decode_calls']} decode steps for "
          f"{st['admitted']} admissions, {st['backpressure']} backpressure")
    for r in done:
        print(f"  req{r.req_id:3d} prompt={r.prompt} -> {r.generated}")
    # admission cost is ceil(S/chunk) steps per prompt, never S — and
    # prefix sharing can only LOWER it (shared pages skip their chunks)
    expected = sum(math.ceil(len(r.prompt) / engine.chunk) for r in done)
    if engine._can_share:
        assert st["prefill_calls"] <= expected, (st["prefill_calls"],
                                                 expected)
        print(f"  prefix sharing: {st['shared_pages']} pages attached, "
              f"{st['shared_tokens']} prompt tokens skipped prefill, "
              f"{st['cow_copies']} copy-on-write")
    else:
        assert st["prefill_calls"] == expected, (st["prefill_calls"],
                                                 expected)
    if cfg.n_experts:
        # MoE capacity-factor dropping couples slots through the shared
        # per-batch expert budget (ROADMAP "MoE chunked-prefill parity"),
        # so same-prompt equality and generate() parity don't hold here
        print("MoE arch: slot-isolation/parity self-checks skipped "
              "(capacity dropping is batch-coupled)")
        return
    if args.temperature > 0 or args.rep_penalty != 1.0:
        # sampled slots use per-slot PRNG streams / penalized logits, so
        # the greedy parity self-checks below don't apply
        print("sampling on: greedy parity self-checks skipped")
        return
    # same-prompt requests must decode identically (slot isolation)
    assert done[-1].generated == done[-2].generated
    ref = generate(params, cfg,
                   jax.numpy.asarray([[7, 8, 9, 10]], jax.numpy.int32),
                   max_new=5)[0, 4:].tolist()
    assert done[-1].generated == ref, (done[-1].generated, ref)
    print("late-arrival decode == fresh generate() ✓")
    if args.paged:
        other, _ = serve(params, cfg, args, paged=False)
        dense = sorted(other.finished, key=lambda r: r.req_id)
        assert [r.generated for r in done] == [r.generated for r in dense]
        print("paged decode == dense decode ✓")
        if engine._can_share:
            private, _ = serve(params, cfg, args, paged=True, share=False)
            ns = sorted(private.finished, key=lambda r: r.req_id)
            assert [r.generated for r in done] == [r.generated for r in ns]
            assert st["prefill_calls"] <= private.stats["prefill_calls"]
            print("prefix-shared decode == private-pages decode ✓")
        if args.kernel:
            scan, _ = serve(params, cfg, args, paged=True, use_kernel=False)
            spath = sorted(scan.finished, key=lambda r: r.req_id)
            assert [r.generated for r in done] == [r.generated
                                                   for r in spath]
            print("kernel decode == scan-path decode ✓")


if __name__ == "__main__":
    main()
