"""Quickstart: build a small decoder from the public API, train it on the
synthetic stream until the loss approaches the analytic optimum, then
generate from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data.synthetic import SyntheticConfig, SyntheticLM
from repro.serve.engine import generate
from repro.train.trainer import TrainConfig, Trainer


def main():
    # any assigned architecture works here; qwen3-8b's reduced variant is a
    # 2-layer GQA decoder with qk-norm
    cfg = get_smoke_config("qwen3-8b")
    cfg = dataclasses.replace(cfg, vocab_size=128)

    loader = SyntheticLM(SyntheticConfig(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, noise=0.05))
    print(f"model: {cfg.name}  ({cfg.n_layers}L, d={cfg.d_model})")
    print(f"optimal loss of the stream ≈ {loader.optimal_loss():.3f}")

    trainer = Trainer(cfg, TrainConfig(steps=80, lr=3e-3, warmup=10,
                                       log_every=20), loader)
    trainer.fit()

    prompts = loader.batch(999)["tokens"][:2, :8]
    out = generate(trainer.params, cfg, prompts, max_new=12)
    print("prompt     :", prompts[0].tolist())
    print("generated  :", out[0, 8:].tolist())
    # the stream is t+1 = hash(t) 95% of the time; check the model learned it
    from repro.data.synthetic import _hash_next
    import numpy as np
    pred = out[0, 8:].tolist()
    hits = sum(int(pred[i + 1] == _hash_next(np.array(pred[i]),
                                             cfg.vocab_size))
               for i in range(len(pred) - 1))
    print(f"hash-rule hits in generation: {hits}/{len(pred)-1}")


if __name__ == "__main__":
    main()
