"""Cluster estimator: the paper's §4 analysis as a tool.  Describe a
fleet and a model; get pipeline latency, steady-state throughput, cost
efficiency and compression what-ifs.

    PYTHONPATH=src python examples/estimate_cluster.py \
        --fleet rtx3080:50 --model bert-large --link wan_1gbps
    PYTHONPATH=src python examples/estimate_cluster.py \
        --fleet h100:4 --model gpt3-24l --link nvlink
"""
import argparse

from repro.configs import ALL_ARCHS, get_config
from repro.core.compression import CompressionSpec
from repro.core.dag import build_model_dag
from repro.core.decomposer import decompose_contiguous, part_stats
from repro.core.perfmodel import (DEVICE_CATALOG, LINK_REGIMES, PerfModel,
                                  make_fleet)
from repro.core.pipeline import estimate_system


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fleet", default="rtx3080:50",
                    help="comma list of device:count")
    ap.add_argument("--model", choices=list(ALL_ARCHS), default="bert-large")
    ap.add_argument("--link", choices=list(LINK_REGIMES), default="wan_1gbps")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--n-batches", type=int, default=512)
    ap.add_argument("--lam", type=float, default=0.75,
                    help="λ_p scaling-down factor (§3.7)")
    args = ap.parse_args()

    spec = [(d, int(n)) for d, n in
            (kv.split(":") for kv in args.fleet.split(","))]
    cfg = get_config(args.model)
    dag = build_model_dag(cfg, batch=args.batch, seq=args.seq,
                          kind="inference")
    nodes = make_fleet(spec, LINK_REGIMES[args.link], lam=args.lam)
    pm = PerfModel(nodes)
    est = estimate_system(dag, pm, [n.node_id for n in nodes],
                          n_batches=args.n_batches, batch_size=args.batch)
    price = sum(DEVICE_CATALOG[d].price_usd * n for d, n in spec)

    print(f"model {cfg.name}: {dag.total_flops()/1e12:.2f} TFLOP/batch, "
          f"{dag.total_param_bytes()/1e9:.2f} GB params")
    print(f"fleet {args.fleet} over {args.link} (λ={args.lam})")
    print(f"  stages                : {est['n_stages']:.0f}")
    print(f"  single-batch latency  : {est['latency_s']:.3f} s   (Eq. 3)")
    print(f"  {args.n_batches} batches pipelined : "
          f"{est['pipelined_s_eq4']:.2f} s   (Eq. 4; sim "
          f"{est['pipelined_s_sim']:.2f} s)")
    print(f"  throughput            : {est['throughput_samples_s']:.2f} "
          f"samples/s")
    print(f"  pipeline bubble       : {est['bubble_fraction']*100:.1f} %")
    if price:
        print(f"  fleet price           : ${price:,.0f}  -> "
              f"{est['throughput_samples_s']/price*1000:.2f} "
              f"samples/s/k$")

    # compression what-ifs on the bottleneck link (activation traffic)
    act = max(s["out_bytes"] for s in part_stats(dag, decompose_contiguous(
        dag, len(nodes))))
    link = LINK_REGIMES[args.link]
    print("  activation transfer per cut "
          f"({act/1e6:.1f} MB raw):")
    for c in [CompressionSpec("none"), CompressionSpec("int8"),
              CompressionSpec("topk", ratio=0.01)]:
        t = link.time(c.bytes(int(act / 4), raw_bytes=act))
        print(f"    {c.kind:8s}: {t*1e3:9.1f} ms/hop")


if __name__ == "__main__":
    main()
