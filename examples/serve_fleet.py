"""Fleet serving example: a broker-routed multi-engine fleet surviving a
mid-decode replica failure.

Three engine replicas on heterogeneous simulated devices (one rtx4090,
two rtx3080) plus one rtx3080 standby share a FIFO queue; the router
places each request on the replica minimizing the Eq. 2-style estimated
completion time, so the fast device serves more of a uniform workload.
A broker heartbeat round then kills one rtx3080 replica mid-decode
(deterministically — its node's reliability is 0): the standby is
drafted by SPEED MATCH from the backup pool (rtx3080 replaces rtx3080,
not the fast peer), the dead replica's in-flight requests re-prefill
from their prompts on the survivors, and the example asserts that

* every submitted request still completes with its full max_new tokens,
* requests served by UNAFFECTED replicas are bitwise-identical to a
  no-failure run of the same fleet (slot isolation + greedy decode),
* re-queued requests produce the same tokens too (same params, greedy —
  re-prefill is exact, whichever replica picks them up).

Every request opens with the same full-page system prompt, so the run
also demonstrates content-addressed prefix sharing: each replica stores
that page once and attaches it (refcount++) on every later admission —
including failover requeues, whose drained requests carry their prefix
digests so the router co-locates them with their shared pages.

A final degraded-mode act injects a ``FaultPlan`` on a healthy fleet:
the fast replica straggles (soft-drain moves its work — by verified
KV-page migration when a compatible peer has room, so moved requests
keep their pages and generated tokens and pay no retry; requeue-from-
prompt is the fallback), one rtx3080 is network-partitioned (its
requests freeze and resume after heal with no re-prefill), and the run
still completes every request "ok", bitwise-equal to the calm run.

    PYTHONPATH=src python examples/serve_fleet.py
"""
import argparse

import jax

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServingEngine
from repro.serve.router import FleetRouter, sim_node


def build_fleet(params, cfg, *, kill_rtx3080: bool, plan=None):
    """3 active replicas + 1 standby.  ``kill_rtx3080`` sets replica 1's
    node reliability to 0 so the FIRST heartbeat round kills it; ``plan``
    optionally injects a degraded-mode fault schedule."""
    def engine():
        return ServingEngine(params, cfg, slots=2, cache_len=64, chunk=8,
                             paged=True, page_size=16)
    nodes = [sim_node("rtx4090", reliability=1.0),
             sim_node("rtx3080", reliability=0.0 if kill_rtx3080 else 1.0),
             sim_node("rtx3080", reliability=1.0)]
    return FleetRouter([(engine(), n) for n in nodes],
                       [(engine(), sim_node("rtx3080", reliability=1.0))],
                       seed=0, fault_plan=plan)


SYSTEM = list(range(40, 56))        # one full shared system-prompt page


def serve(router, cfg, n_requests, heartbeat_every):
    for i in range(n_requests):
        tail = [(3 + 5 * i + j) % cfg.vocab_size for j in range(4 + i % 3)]
        router.submit(Request(i, SYSTEM + tail, max_new=8))
    router.run(heartbeat_every=heartbeat_every)
    return {r.req_id: r.generated for r in router.finished}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ALL_ARCHS), default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)

    # reference: same fleet, no failure
    calm = build_fleet(params, cfg, kill_rtx3080=False)
    ref = serve(calm, cfg, args.requests, heartbeat_every=0)

    # failure run: heartbeat every 2 ticks, replica 1 dies on the first one
    stormy = build_fleet(params, cfg, kill_rtx3080=True)
    out = serve(stormy, cfg, args.requests, heartbeat_every=2)
    st = stormy.stats

    print(f"{cfg.name} fleet: {args.requests} requests, replica 1 "
          f"(rtx3080) killed mid-decode by heartbeat round 1")
    print(f"  router: {st['failures']} failure, {st['requeued']} in-flight "
          f"requests requeued, {st['replacements']} standby drafted")
    for rep in stormy.replicas:
        state = "live" if rep.alive else "DEAD"
        print(f"  replica {rep.replica_id} [{rep.node.device.name}, "
              f"{state}]: served {sorted(rep.served)}")

    # every submitted request completed, none dropped or truncated
    assert sorted(out) == list(range(args.requests)), sorted(out)
    assert all(len(g) == 8 for g in out.values())
    assert st["failures"] == 1 and st["replacements"] == 1
    # the drafted replacement speed-matches the dead rtx3080 (not rtx4090)
    drafted = stormy.replicas[-1]
    assert drafted.node.device.name == "rtx3080", drafted.node.device.name
    print("speed-matched standby drafted ✓")
    # placement skew: the rtx4090 replica served the most requests
    fast = stormy.replicas[0]
    assert all(len(fast.served) >= len(r.served)
               for r in stormy.replicas if r.alive)
    # bitwise parity with the no-failure run — for EVERY request (shared
    # params + greedy decode make re-prefill exact), which subsumes the
    # unaffected replicas
    assert out == ref
    print(f"all {args.requests} requests complete, outputs bitwise-equal "
          f"to the no-failure run ✓")
    # every request opens with the same full-page system prompt: replicas
    # serving more than one stored that page ONCE (content-addressed,
    # refcounted) and skipped its prefill chunks on every re-hit — the
    # parity assert above already proved sharing never changed a token
    if all(r.engine._can_share for r in stormy.replicas):
        shared = sum(r.engine.stats["shared_pages"]
                     for r in stormy.replicas)
        cow = sum(r.engine.stats["cow_copies"] for r in stormy.replicas)
        assert shared > 0, "system-prompt page never shared"
        print(f"prefix sharing: {shared} page attaches fleet-wide "
              f"({cow} copy-on-write), outputs unchanged ✓")

    # act 3 — degraded mode without any death: a FaultPlan straggles the
    # fast replica (its tick-latency EWMA crosses the drain threshold ->
    # in-flight work soft-drains: verified KV-page migration to a peer
    # with room, zero retries charged; requeue-from-prompt with digests
    # preserved when no destination fits) and partitions one rtx3080
    # (its requests FREEZE in place and resume after heal with no
    # re-dispatch and no re-prefill); every request still completes
    # "ok", bitwise-equal to the calm run
    from repro.serve.faults import Fault, FaultPlan
    plan = FaultPlan()
    plan.add(Fault(tick=2, replica_id=0, kind="straggle", factor=6.0,
                   duration=6))
    plan.add(Fault(tick=3, replica_id=2, kind="partition", duration=4))
    degraded = build_fleet(params, cfg, kill_rtx3080=False, plan=plan)
    for i in range(args.requests):
        tail = [(3 + 5 * i + j) % cfg.vocab_size for j in range(4 + i % 3)]
        degraded.submit(Request(i, SYSTEM + tail, max_new=8))
    res = degraded.run()
    st = degraded.stats
    print(f"degraded run: outcomes " + ", ".join(
        f"{k}={v}" for k, v in sorted(res.outcomes().items())))
    print(f"  {st['straggles']} straggle ticks -> {st['soft_drains']} "
          f"soft-drain ({st['migrations']} migrated with pages+tokens, "
          f"{st['requeued']} requeued from prompt), "
          f"{st['partitions']} partition -> {st['partition_heals']} "
          f"healed in place")
    assert res.ok, res.outcomes()
    assert st["soft_drains"] >= 1, "straggler never crossed drain EWMA"
    # drained work went SOMEWHERE: live migration (state + pages move,
    # no retry) or the digest-preserving requeue fallback
    assert st["migrations"] + st["requeued"] >= 1, st
    assert st["partitions"] == 1 and st["partition_heals"] == 1
    assert {r.req_id: r.generated for r in res.completed} == ref
    print("straggler drained, partition healed, outputs bitwise-equal ✓")


if __name__ == "__main__":
    main()
