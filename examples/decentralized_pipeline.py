"""The FusionAI showcase: decentralized pipeline training over a
heterogeneous consumer-GPU fleet — broker, DAG decomposition, scheduling,
FP/BP/Update execution with message passing, a mid-training node failure
with backup-pool replacement, and the TPU-native SPMD pipeline mapping.

    PYTHONPATH=src python examples/decentralized_pipeline.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.broker import Broker
from repro.core.dag import build_model_dag
from repro.core.decomposer import decompose_contiguous
from repro.core.executor import LocalCluster, spmd_pipeline
from repro.core.perfmodel import LINK_REGIMES, PerfModel, make_fleet
from repro.core.pipeline import estimate_system
from repro.data.synthetic import SyntheticConfig, SyntheticLM


def main():
    cfg = dataclasses.replace(get_smoke_config("gpt3-24l"), vocab_size=256)
    B, S = 4, 32
    dag = build_model_dag(cfg, batch=B, seq=S, kind="train")
    print(f"IR plane: {len(dag)} ops, {dag.total_flops()/1e9:.2f} GFLOP/step, "
          f"{dag.total_param_bytes()/1e6:.1f} MB params")

    # --- broker: register a heterogeneous fleet, schedule the job --------
    broker = Broker(backup_fraction=0.25, seed=0)
    fleet = make_fleet([("rtx3080", 4), ("rtx4090", 2), ("rtx4080", 2)],
                       LINK_REGIMES["wan_1gbps"])
    for node in fleet:
        node.reliability = 0.98
        broker.register(node)
    sched = broker.submit_job(dag, n_parts=3)
    print(f"broker: {len(broker.active)} active + {len(broker.backup)} backup"
          f" nodes; schedule makespan {sched.makespan*1e3:.1f} ms "
          f"(feasible={sched.feasible})")

    # --- execution plane: pipeline-parallel FP/BP/Update ------------------
    parts = decompose_contiguous(dag, 3)
    cluster = LocalCluster(dag, parts, cfg, jax.random.PRNGKey(0))
    lm = SyntheticLM(SyntheticConfig(cfg.vocab_size, S, B, noise=0.05))
    print("decentralized training (3 compnodes):")
    for step in range(8):
        batch = lm.batch(step)
        loss = cluster.train_step(batch["tokens"], batch["labels"], lr=3e-3)
        if step % 2 == 0:
            print(f"  step {step}: loss {loss:.4f}  "
                  f"(bus traffic {cluster.bus.total_bytes/1e6:.2f} MB)")

    # --- fault tolerance: kill a node mid-job, draft a backup -------------
    victim = sched.assignment[0]
    print(f"simulating failure of compnode {victim} ...")
    broker.quit(victim, graceful=False)
    repl = [e for e in broker.events if e.kind == "replace"]
    print(f"  broker drafted replacement: {repl[-1].detail if repl else 'n/a'}")
    assert all(nid in broker.active
               for nid in broker.schedule.assignment.values())
    print("  all tasks remapped to online nodes ✓")

    # --- analytic estimate for this exact job (§4) ------------------------
    pm = PerfModel(fleet)
    est = estimate_system(dag, pm, [n.node_id for n in fleet[:3]],
                          n_batches=64, batch_size=B)
    print(f"analytic: latency {est['latency_s']*1e3:.1f} ms, pipelined x64 "
          f"batches {est['pipelined_s_eq4']:.2f} s, bubble "
          f"{est['bubble_fraction']*100:.0f}%")

    # --- production mapping: shard_map pipeline over 4 host devices ------
    n_dev = len(jax.devices())
    if n_dev >= 4:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(4)
        d = 32
        stage_w = jax.random.normal(jax.random.PRNGKey(1), (4, d, d)) * 0.2
        xs = jax.random.normal(jax.random.PRNGKey(2), (8, B, d))
        out = spmd_pipeline(lambda w, x: jnp.tanh(x @ w), stage_w, xs, mesh,
                            axis="stage")
        ref = xs
        for i in range(4):
            ref = jnp.tanh(ref @ stage_w[i])
        err = float(jnp.abs(out - ref).max())
        print(f"spmd_pipeline over {n_dev} devices "
              f"(collective_permute GPipe): max err vs sequential {err:.2e}")


if __name__ == "__main__":
    main()
