"""End-to-end training driver: train a ~100M-parameter decoder for a few
hundred steps with checkpointing and resume.

    PYTHONPATH=src python examples/train_e2e.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train_e2e.py --preset 20m  --steps 150

The 100m preset is the deliverable configuration (sized for a real
accelerator); the 20m preset exercises the identical path in CPU-hours
budgets.  Loss must approach the stream's analytic optimum.
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.data.synthetic import SyntheticConfig, SyntheticLM
from repro.models.config import ModelConfig
from repro.train.trainer import TrainConfig, Trainer

PRESETS = {
    # ~103M params: 12L, d=768, vocab 32k (GPT-2-small-like)
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
                 head_dim=64, d_ff=3072, vocab_size=32000,
                 seq=512, batch=8),
    # ~19M params: CPU-friendly
    "20m": dict(n_layers=6, d_model=384, n_heads=6, n_kv_heads=6,
                head_dim=64, d_ff=1536, vocab_size=8192,
                seq=256, batch=8),
    # ~3M: smoke
    "3m": dict(n_layers=4, d_model=192, n_heads=4, n_kv_heads=4,
               head_dim=48, d_ff=768, vocab_size=2048,
               seq=128, batch=8),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="20m")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ModelConfig(
        name=f"e2e-{args.preset}", arch_type="dense",
        n_layers=p["n_layers"], d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], head_dim=p["head_dim"], d_ff=p["d_ff"],
        vocab_size=p["vocab_size"])
    print(f"{cfg.name}: {cfg.param_counts()['total']/1e6:.1f}M params")

    loader = SyntheticLM(SyntheticConfig(
        vocab_size=cfg.vocab_size, seq_len=p["seq"], global_batch=p["batch"],
        noise=0.05))
    print(f"optimal loss ≈ {loader.optimal_loss():.3f}")
    trainer = Trainer(cfg, TrainConfig(
        steps=args.steps, lr=args.lr, warmup=max(10, args.steps // 20),
        log_every=max(1, args.steps // 20), ckpt_every=args.steps // 3,
        ckpt_dir=args.ckpt_dir), loader)
    if args.resume:
        trainer.maybe_restore()
        print(f"resumed at step {trainer.start_step}")
    hist = trainer.fit()
    print(f"final loss {hist[-1]['loss']:.4f} "
          f"(optimum {loader.optimal_loss():.3f}); "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
