"""Checkpointing: pytree <-> npz with a JSON manifest, step-numbered
directories, retention policy, and atomic writes (write to tmp, rename).
Works for params, optimizer state and data-iterator cursors alike.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _to_numpy(leaf) -> np.ndarray:
    """bfloat16 has no native numpy dtype npz can store: round-trip
    through float32 (manifest keeps the original dtype)."""
    leaf = jnp.asarray(leaf)
    if leaf.dtype == jnp.bfloat16:
        return np.asarray(leaf.astype(jnp.float32))
    return np.asarray(leaf)


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Atomically write ``tree`` under ckpt_dir/step_<n>/; prune old."""
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves = _flatten_with_paths(tree)
    treedef = jax.tree.structure(tree)
    arrays = {f"a{i}": _to_numpy(leaf) for i, (_, leaf) in enumerate(leaves)}
    manifest = {
        "step": step,
        "keys": [k for k, _ in leaves],
        "treedef": str(treedef),
        "dtypes": [str(jnp.asarray(l).dtype) for _, l in leaves],
    }
    tmp = tempfile.mkdtemp(dir=ckpt_dir)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def restore(ckpt_dir: str, tree_like, step: Optional[int] = None):
    """Restore into the structure of ``tree_like`` (shape/dtype checked)."""
    step = step if step is not None else latest_step(ckpt_dir)
    assert step is not None, f"no checkpoints under {ckpt_dir}"
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    ref_leaves, treedef = jax.tree.flatten(tree_like)
    arrays = [data[f"a{i}"] for i in range(len(manifest["keys"]))]
    assert len(arrays) == len(ref_leaves), (
        f"checkpoint has {len(arrays)} leaves, expected {len(ref_leaves)}")
    out = []
    for ref, arr in zip(ref_leaves, arrays):
        assert tuple(arr.shape) == tuple(jnp.shape(ref)), \
            f"shape mismatch {arr.shape} vs {jnp.shape(ref)}"
        out.append(jnp.asarray(arr).astype(jnp.asarray(ref).dtype))
    return treedef.unflatten(out), step
