"""Checked-in baseline of grandfathered findings.

The analyzer fails CI only on NEW violations: every finding is matched
against the baseline by ``(rule, path, symbol)`` — line numbers drift
too much to anchor on — with a per-key count, so adding a SECOND
violation next to a baselined one still fails.  Every entry carries a
one-line human justification (reviewed like code); entries that no
longer match anything are STALE and expire: ``--strict`` refuses them,
``--write-baseline`` drops them.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.analysis.core import Finding

BASELINE_NAME = ".repro-lint-baseline.json"


@dataclass
class BaselineEntry:
    rule: str
    path: str
    symbol: str
    count: int
    justification: str

    @property
    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.path, self.symbol)


@dataclass
class Baseline:
    entries: List[BaselineEntry] = field(default_factory=list)

    def by_key(self) -> Dict[Tuple[str, str, str], BaselineEntry]:
        return {e.key: e for e in self.entries}


def load_baseline(path: Path) -> Baseline:
    if not Path(path).exists():
        return Baseline()
    data = json.loads(Path(path).read_text())
    entries = [BaselineEntry(**e) for e in data.get("entries", [])]
    return Baseline(entries=entries)


def write_baseline(path: Path, findings: List[Finding],
                   old: Optional[Baseline] = None) -> Baseline:
    """Rewrite the baseline to exactly the CURRENT findings: new keys get
    a TODO justification (fill it in before committing), kept keys keep
    their justification, stale keys are dropped."""
    counts: Dict[Tuple[str, str, str], int] = {}
    for f in findings:
        counts[f.key] = counts.get(f.key, 0) + 1
    prior = (old or Baseline()).by_key()
    entries = [
        BaselineEntry(rule=r, path=p, symbol=s, count=n,
                      justification=(prior[(r, p, s)].justification
                                     if (r, p, s) in prior
                                     else "TODO: justify this baseline"))
        for (r, p, s), n in sorted(counts.items())]
    blob = {"version": 1,
            "comment": "grandfathered repro-lint findings; see "
                       "src/repro/analysis/README.md",
            "entries": [asdict(e) for e in entries]}
    Path(path).write_text(json.dumps(blob, indent=2) + "\n")
    return Baseline(entries=entries)


def apply_baseline(findings: List[Finding], baseline: Baseline
                   ) -> Tuple[List[Finding], List[Finding],
                              List[BaselineEntry]]:
    """Split findings into (new, grandfathered) and report stale
    baseline entries (matched zero findings — the violation was fixed,
    so the entry must expire)."""
    remaining = {e.key: e.count for e in baseline.entries}
    matched = {e.key: 0 for e in baseline.entries}
    new: List[Finding] = []
    old: List[Finding] = []
    for f in findings:
        if remaining.get(f.key, 0) > 0:
            remaining[f.key] -= 1
            matched[f.key] += 1
            old.append(f)
        else:
            new.append(f)
    stale = [e for e in baseline.entries if matched[e.key] == 0]
    return new, old, stale
