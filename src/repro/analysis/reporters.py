"""Text and JSON reporters over an analysis run + baseline split."""
from __future__ import annotations

import json
from dataclasses import asdict
from typing import List, Optional, TextIO

from repro.analysis.baseline import BaselineEntry
from repro.analysis.core import RULES, Finding, Report


def render_text(report: Report, new: List[Finding], old: List[Finding],
                stale: List[BaselineEntry], out: TextIO) -> None:
    for f in new:
        out.write(f.format() + "\n")
    if stale:
        out.write("\nstale baseline entries (violation fixed — remove "
                  "them or run --write-baseline):\n")
        for e in stale:
            out.write(f"  {e.rule} {e.path} [{e.symbol}] x{e.count} — "
                      f"{e.justification}\n")
    out.write(
        f"\nrepro-lint: {report.files_scanned} file(s), "
        f"{len(new)} new finding(s), {len(old)} baselined, "
        f"{report.suppressed} suppressed, {len(stale)} stale baseline "
        f"entr{'y' if len(stale) == 1 else 'ies'}\n")
    for err in report.parse_errors:
        out.write(f"parse error: {err}\n")


def render_json(report: Report, new: List[Finding], old: List[Finding],
                stale: List[BaselineEntry], out: TextIO) -> None:
    blob = {
        "root": report.root,
        "files_scanned": report.files_scanned,
        "rules": {rid: {"title": r.title, "motivation": r.motivation}
                  for rid, r in sorted(RULES.items())},
        "summary": {
            "new": len(new),
            "baselined": len(old),
            "suppressed": report.suppressed,
            "stale_baseline": len(stale),
            "by_rule": report.by_rule(),
        },
        "findings": [dict(asdict(f), status="new") for f in new]
        + [dict(asdict(f), status="baselined") for f in old],
        "stale_baseline": [asdict(e) for e in stale],
        "parse_errors": report.parse_errors,
    }
    json.dump(blob, out, indent=2)
    out.write("\n")


def render(fmt: str, report: Report, new: List[Finding],
           old: List[Finding], stale: List[BaselineEntry],
           out: TextIO) -> None:
    if fmt == "json":
        render_json(report, new, old, stale, out)
    else:
        render_text(report, new, old, stale, out)
