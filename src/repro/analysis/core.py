"""Analyzer core: rule registry, module context, suppressions, driver.

Everything is stdlib ``ast`` — rules get a parsed module plus shared
helpers (import resolution to fully-qualified dotted names, constant
folding for shape arithmetic, enclosing-symbol lookup) and yield
``Finding``s.  Suppression is per line (``# repro-lint: disable=RULE``);
grandfathered findings live in a checked-in baseline (see baseline.py)
so CI fails only on NEW violations.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

# directories scanned when no explicit paths are given (relative to root)
DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples")

DEFAULT_CONFIG = {
    # per-grid-step VMEM footprint budget for PAL001 (bytes).  The guide
    # pegs VMEM at ~16 MB/core; block shapes must leave room for
    # double-buffering, so the default budget is half of that.
    "vmem_budget": 8 * 1024 * 1024,
    # assumed itemsize for operand blocks whose dtype is not statically
    # known (f32/int32 repo default)
    "default_itemsize": 4,
}

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,\s]+)")


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location.  ``symbol`` (the
    enclosing def/class qualname) anchors baseline entries, so they
    survive line drift."""
    rule: str
    path: str          # root-relative posix path
    line: int
    col: int
    message: str
    symbol: str = "<module>"

    @property
    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.path, self.symbol)

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{self.symbol}] {self.message}")


class Rule:
    """Base class; subclasses register with ``@register`` and implement
    ``check(ctx) -> iterable[Finding]``."""
    rule_id: str = ""
    title: str = ""
    motivation: str = ""     # the PR/bug that made the invariant real

    def check(self, ctx: "ModuleContext") -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, ctx: "ModuleContext", node: ast.AST,
                message: str) -> Finding:
        return Finding(self.rule_id, ctx.rel, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), message,
                       ctx.symbol_at(getattr(node, "lineno", 1)))


RULES: Dict[str, Rule] = {}


def register(cls):
    assert cls.rule_id and cls.rule_id not in RULES, cls
    RULES[cls.rule_id] = cls()
    return cls


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """'np.random.rand' for a pure Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


class ImportMap:
    """Local name -> fully-qualified dotted module/object name."""

    def __init__(self, tree: ast.Module):
        self.names: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.names[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and not node.level:
                for a in node.names:
                    self.names[a.asname or a.name] = \
                        f"{node.module}.{a.name}"

    def resolve(self, dotted: Optional[str]) -> Optional[str]:
        """Expand the first segment through the import table:
        'np.random.rand' -> 'numpy.random.rand'."""
        if not dotted:
            return None
        head, _, rest = dotted.partition(".")
        full = self.names.get(head, head)
        return f"{full}.{rest}" if rest else full


def const_int(node: ast.AST, env: Dict[str, int]) -> Optional[int]:
    """Fold an int expression over literals + ``env`` names, or None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = const_int(node.operand, env)
        return None if v is None else -v
    if isinstance(node, ast.BinOp):
        lhs, rhs = const_int(node.left, env), const_int(node.right, env)
        if lhs is None or rhs is None:
            return None
        try:
            if isinstance(node.op, ast.Add):
                return lhs + rhs
            if isinstance(node.op, ast.Sub):
                return lhs - rhs
            if isinstance(node.op, ast.Mult):
                return lhs * rhs
            if isinstance(node.op, ast.FloorDiv):
                return lhs // rhs
            if isinstance(node.op, ast.Mod):
                return lhs % rhs
            if isinstance(node.op, ast.Pow):
                return lhs ** rhs
        except (ZeroDivisionError, OverflowError):
            return None
    return None


def const_int_tuple(node: ast.AST,
                    env: Dict[str, int]) -> Optional[Tuple[int, ...]]:
    if not isinstance(node, (ast.Tuple, ast.List)):
        return None
    out = []
    for el in node.elts:
        v = const_int(el, env)
        if v is None:
            return None
        out.append(v)
    return tuple(out)


def int_env(tree: ast.Module) -> Dict[str, int]:
    """Module-level ``NAME = <int literal>`` constants (last wins)."""
    env: Dict[str, int] = {}
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            v = const_int(stmt.value, env)
            if v is not None:
                env[stmt.targets[0].id] = v
    return env


# ---------------------------------------------------------------------------
# module context
# ---------------------------------------------------------------------------

class ModuleContext:
    """One parsed source file plus the helpers every rule needs."""

    def __init__(self, text: str, rel: str, config: Optional[dict] = None):
        self.text = text
        self.rel = rel.replace("\\", "/")
        self.lines = text.splitlines()
        self.tree = ast.parse(text)
        self.config = dict(DEFAULT_CONFIG, **(config or {}))
        self.imports = ImportMap(self.tree)
        self.module_ints = int_env(self.tree)
        # (start, end, qualname) intervals for enclosing-symbol lookup
        self._symbols: List[Tuple[int, int, str]] = []
        self._collect_symbols(self.tree, [])
        self.suppressed: Dict[int, set] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(line)
            if m:
                self.suppressed[i] = {
                    r.strip().upper() for r in m.group(1).split(",")
                    if r.strip()}

    def _collect_symbols(self, node: ast.AST, stack: List[str]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                qual = ".".join(stack + [child.name])
                end = getattr(child, "end_lineno", child.lineno)
                self._symbols.append((child.lineno, end, qual))
                self._collect_symbols(child, stack + [child.name])
            else:
                self._collect_symbols(child, stack)

    def symbol_at(self, line: int) -> str:
        best, best_span = "<module>", None
        for lo, hi, qual in self._symbols:
            if lo <= line <= hi:
                span = hi - lo
                if best_span is None or span <= best_span:
                    best, best_span = qual, span
        return best

    def is_suppressed(self, f: Finding) -> bool:
        rules = self.suppressed.get(f.line)
        return bool(rules) and (f.rule.upper() in rules or "ALL" in rules)

    def resolve(self, node: ast.AST) -> Optional[str]:
        return self.imports.resolve(dotted_name(node))

    def functions(self) -> Iterator[ast.FunctionDef]:
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield node


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

@dataclass
class Report:
    root: str
    files_scanned: int = 0
    findings: List[Finding] = field(default_factory=list)
    suppressed: int = 0
    parse_errors: List[str] = field(default_factory=list)

    def by_rule(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.findings:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out


def _load_rules(only: Optional[Sequence[str]] = None) -> List[Rule]:
    import repro.analysis.rules  # noqa: F401  (registers on import)
    if not only:
        return [RULES[k] for k in sorted(RULES)]
    want = {o.strip().upper() for o in only if o.strip()}
    unknown = want - set(RULES)
    if unknown:
        raise ValueError(
            f"unknown rule(s) {sorted(unknown)}; known: {sorted(RULES)}")
    return [RULES[k] for k in sorted(want)]


def analyze_source(text: str, rel: str, *,
                   only: Optional[Sequence[str]] = None,
                   config: Optional[dict] = None,
                   count_suppressed: Optional[List[int]] = None
                   ) -> List[Finding]:
    """Run the (selected) rules over one in-memory source file.  ``rel``
    decides path-scoped rules (e.g. DET001 only fires under
    src/repro/{core,serve,models,kernels})."""
    ctx = ModuleContext(text, rel, config)
    out: List[Finding] = []
    n_sup = 0
    for rule in _load_rules(only):
        for f in rule.check(ctx):
            if ctx.is_suppressed(f):
                n_sup += 1
            else:
                out.append(f)
    if count_suppressed is not None:
        count_suppressed.append(n_sup)
    out.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return out


def repo_root() -> Path:
    """The repo this package ships in (src/repro/analysis -> repo)."""
    return Path(__file__).resolve().parents[3]


def iter_py_files(root: Path,
                  paths: Optional[Sequence[str]] = None) -> Iterator[Path]:
    for rel in (paths or DEFAULT_PATHS):
        base = (root / rel).resolve()
        if base.is_file() and base.suffix == ".py":
            yield base
            continue
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*.py")):
            if any(part.startswith(".") or part == "__pycache__"
                   for part in p.relative_to(root).parts):
                continue
            yield p


def run_analysis(root: Optional[Path] = None, *,
                 paths: Optional[Sequence[str]] = None,
                 only: Optional[Sequence[str]] = None,
                 config: Optional[dict] = None) -> Report:
    root = Path(root) if root else repo_root()
    report = Report(root=str(root))
    for path in iter_py_files(root, paths):
        rel = path.relative_to(root).as_posix()
        try:
            text = path.read_text()
            sup: List[int] = []
            found = analyze_source(text, rel, only=only, config=config,
                                   count_suppressed=sup)
        except (SyntaxError, UnicodeDecodeError) as e:
            report.parse_errors.append(f"{rel}: {e}")
            continue
        report.files_scanned += 1
        report.findings.extend(found)
        report.suppressed += sup[0] if sup else 0
    report.findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return report
