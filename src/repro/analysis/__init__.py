"""repro.analysis — static invariant checker for the jit / Pallas /
allocator planes.

The paper's compatibility story abstracts an intermediate-representation
plane and an execution plane so heterogeneous consumer devices can run
the same DAG; a plan that compiles wrong on one peer poisons the whole
run (FusionLLM, arXiv:2410.12707, makes the same point for
geo-distributed training).  This repo's equivalents are invariants every
PR since PR 2 has paid for at runtime — bitwise-deterministic replay,
donation-safe jitted steps, Pallas BlockSpec/grid consistency, and the
refcount-paired page lifecycle.  Runtime tests only catch a violation
they happen to execute; this package checks the SOURCE, at review time,
before a bad plan ships to a fleet that cannot be single-stepped.

Pure stdlib (``ast``) — no new dependencies.  Entry points:

* ``python -m repro.analysis [--strict] [--only RULE] [--format json]``
* ``run_analysis(root)`` / ``analyze_source(text, rel)`` for tests.

See ``src/repro/analysis/README.md`` for the rule catalog, suppression
comments (``# repro-lint: disable=RULE``) and the baseline workflow.
"""
from repro.analysis.baseline import (Baseline, BaselineEntry, apply_baseline,
                                     load_baseline, write_baseline)
from repro.analysis.core import (DEFAULT_CONFIG, RULES, Finding, Report,
                                 analyze_source, iter_py_files, repo_root,
                                 run_analysis)

__all__ = [
    "Baseline", "BaselineEntry", "DEFAULT_CONFIG", "Finding", "RULES",
    "Report", "analyze_source", "apply_baseline", "iter_py_files",
    "load_baseline", "repo_root", "run_analysis", "write_baseline",
]
