"""CLI: ``python -m repro.analysis [--strict] [--only RULE] ...``.

Exit codes: 0 clean (no new findings; --strict also requires no stale
baseline entries and no parse errors), 1 violations, 2 usage error.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.baseline import (BASELINE_NAME, apply_baseline,
                                     load_baseline, write_baseline)
from repro.analysis.core import DEFAULT_PATHS, RULES, repo_root, run_analysis
from repro.analysis.reporters import render


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="stdlib-ast static invariant checker for the "
                    "jit / Pallas / allocator planes "
                    "(src/repro/analysis/README.md)")
    p.add_argument("paths", nargs="*",
                   help=f"files/dirs relative to --root "
                        f"(default: {' '.join(DEFAULT_PATHS)})")
    p.add_argument("--root", type=Path, default=None,
                   help="repo root (default: autodetected from the "
                        "installed package)")
    p.add_argument("--only", action="append", default=[],
                   help="run only these rule(s); repeatable or "
                        "comma-separated (e.g. --only DET001,PAL001)")
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument("--output", type=Path, default=None,
                   help="write the report here instead of stdout "
                        "(CI artifact)")
    p.add_argument("--baseline", type=Path, default=None,
                   help=f"baseline file (default: <root>/{BASELINE_NAME})")
    p.add_argument("--write-baseline", action="store_true",
                   help="rewrite the baseline to the current findings "
                        "(keeps existing justifications, drops stale "
                        "entries) and exit 0")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore the baseline: report every finding as new")
    p.add_argument("--strict", action="store_true",
                   help="also fail on stale baseline entries and parse "
                        "errors (CI mode)")
    p.add_argument("--vmem-budget", type=int, default=None,
                   help="PAL001 per-grid-step block footprint budget in "
                        "bytes (default 8 MiB)")
    p.add_argument("--list-rules", action="store_true")
    return p


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)

    only = [r for chunk in args.only for r in chunk.split(",") if r.strip()]
    config = {}
    if args.vmem_budget is not None:
        config["vmem_budget"] = args.vmem_budget

    if args.list_rules:
        import repro.analysis.rules  # noqa: F401
        for rid, rule in sorted(RULES.items()):
            print(f"{rid}  {rule.title}\n    why: {rule.motivation}")
        return 0

    root = (args.root or repo_root()).resolve()
    try:
        report = run_analysis(root, paths=args.paths or None,
                              only=only or None, config=config or None)
    except ValueError as e:          # unknown --only rule
        print(f"error: {e}", file=sys.stderr)
        return 2

    baseline_path = args.baseline or (root / BASELINE_NAME)
    if args.write_baseline:
        old = load_baseline(baseline_path)
        new_bl = write_baseline(baseline_path, report.findings, old)
        print(f"wrote {len(new_bl.entries)} baseline entr"
              f"{'y' if len(new_bl.entries) == 1 else 'ies'} to "
              f"{baseline_path}")
        todo = sum(1 for e in new_bl.entries
                   if e.justification.startswith("TODO"))
        if todo:
            print(f"note: {todo} entr{'y needs' if todo == 1 else 'ies need'}"
                  f" a one-line justification before commit")
        return 0

    baseline = load_baseline(baseline_path) if not args.no_baseline \
        else load_baseline(Path("/nonexistent"))
    new, old, stale = apply_baseline(report.findings, baseline)

    out = open(args.output, "w") if args.output else sys.stdout
    try:
        render(args.format, report, new, old, stale, out)
    finally:
        if args.output:
            out.close()
            # CI logs still want the one-line summary on stdout
            print(f"repro-lint: {len(new)} new finding(s), "
                  f"{len(old)} baselined, {len(stale)} stale; report at "
                  f"{args.output}")

    if new:
        return 1
    if args.strict and (stale or report.parse_errors):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
