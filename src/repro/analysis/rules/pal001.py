"""PAL001 — Pallas grid/BlockSpec consistency; PAL002 — cost-plan drift.

A Pallas kernel has three descriptions of the same layout that nothing
type-checks against each other: the grid, the BlockSpec index_maps
(whose arity must be grid rank + scalar-prefetch count, and whose
return tuple must have one coordinate per block dimension), and the
block shapes (whose summed per-step footprint must fit VMEM —
~16 MB/core, and double-buffering halves what a kernel may plan on).
Mosaic reports mismatches as late compile errors on TPU only; on this
CPU container interpret mode happily runs a wrong index_map.  PAL001
checks each ``pl.pallas_call`` / ``pltpu.PrefetchScalarGridSpec`` site
statically and stays silent whenever a piece is not statically visible
(specs passed through a variable built elsewhere, runtime-computed
shapes) — no false positives on dynamic code, by construction.

PAL002 covers the one site PAL001 must skip: a hand-built
``cost_estimate`` next to specs produced by a helper.  The advertised
DMA bytes (CostEstimate.bytes_accessed) steer the paper's
cost-model-driven placement, so the cost must be DERIVED from the same
plan the blocks are built from (``paged_attention._spec_plan`` is the
repo's one-source-of-truth idiom).  The rule resolves the local
function that produced ``in_specs`` and requires the ``cost_estimate``
expression to transitively call it; a literal/disconnected cost next
to helper-built specs is exactly the drift the PR 3→5 cost-model
regressions came from.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import (Finding, ModuleContext, Rule, const_int,
                                 const_int_tuple, dotted_name, register)

_PALLAS_CALL = {"jax.experimental.pallas.pallas_call", "pallas.pallas_call",
                "pl.pallas_call"}
_GRID_SPEC = {"jax.experimental.pallas.tpu.PrefetchScalarGridSpec",
              "pltpu.PrefetchScalarGridSpec"}
_BLOCK_SPEC = {"jax.experimental.pallas.BlockSpec", "pallas.BlockSpec",
               "pl.BlockSpec"}


def _is_call_to(ctx: ModuleContext, node: ast.AST, names: Set[str]
                ) -> bool:
    """Leaf-name match so any Pallas import alias works (`import
    jax.experimental.pallas as pl` resolves the head only)."""
    if not isinstance(node, ast.Call):
        return False
    full = ctx.resolve(node.func)
    if not full:
        return False
    leaf = full.split(".")[-1]
    return full in names or leaf in {n.split(".")[-1] for n in names}


def _kwarg(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _local_env(ctx: ModuleContext, scope: Optional[ast.AST]
               ) -> Dict[str, int]:
    """Module int constants + simple ``name = <int expr>`` assignments in
    the enclosing function (best effort; last write wins)."""
    env = dict(ctx.module_ints)
    if scope is not None:
        for node in ast.walk(scope):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                v = const_int(node.value, env)
                if v is not None:
                    env[node.targets[0].id] = v
    return env


def _index_fn(scope: Optional[ast.AST], node: ast.AST
              ) -> Optional[Tuple[int, Optional[int]]]:
    """(arity, return_rank) of a BlockSpec index_map expression —
    a lambda inline, or a Name bound to a local def/lambda in ``scope``.
    None when the function is not statically visible."""
    if isinstance(node, ast.Lambda):
        arity = len(node.args.posonlyargs) + len(node.args.args)
        rank = len(node.body.elts) \
            if isinstance(node.body, ast.Tuple) else None
        return arity, rank
    if isinstance(node, ast.Name) and scope is not None:
        for sub in ast.walk(scope):
            if isinstance(sub, ast.FunctionDef) and sub.name == node.id:
                arity = len(sub.args.posonlyargs) + len(sub.args.args)
                ranks = {len(r.value.elts) for r in ast.walk(sub)
                         if isinstance(r, ast.Return)
                         and isinstance(r.value, ast.Tuple)}
                return arity, ranks.pop() if len(ranks) == 1 else None
            if isinstance(sub, ast.Assign) \
                    and isinstance(sub.value, ast.Lambda) \
                    and any(isinstance(t, ast.Name) and t.id == node.id
                            for t in sub.targets):
                return _index_fn(scope, sub.value)
    return None


def _spec_exprs(node: Optional[ast.AST]) -> List[ast.AST]:
    if node is None:
        return []
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    return [node]


class _Site:
    """One statically-analyzable pallas_call / PrefetchScalarGridSpec:
    grid rank, scalar-prefetch count, and the visible BlockSpec exprs."""

    def __init__(self, call: ast.Call, scope: Optional[ast.AST],
                 ctx: ModuleContext):
        self.call = call
        self.scope = scope
        self.grid_rank: Optional[int] = None
        self.prefetch = 0
        self.specs: List[ast.Call] = []      # visible pl.BlockSpec calls
        self.out_rank: Optional[int] = None  # rank of out_shape, if visible

        grid = _kwarg(call, "grid")
        spec_nodes = _spec_exprs(_kwarg(call, "in_specs")) \
            + _spec_exprs(_kwarg(call, "out_specs")) \
            + _spec_exprs(_kwarg(call, "out_spec"))

        gs = _kwarg(call, "grid_spec")
        if gs is not None:
            inner = self._resolve_grid_spec(gs, scope, ctx)
            if inner is not None:
                grid = _kwarg(inner, "grid")
                np_ = _kwarg(inner, "num_scalar_prefetch")
                if isinstance(np_, ast.Constant) \
                        and isinstance(np_.value, int):
                    self.prefetch = np_.value
                spec_nodes += _spec_exprs(_kwarg(inner, "in_specs")) \
                    + _spec_exprs(_kwarg(inner, "out_specs"))
        elif _is_call_to(ctx, call, _GRID_SPEC):
            np_ = _kwarg(call, "num_scalar_prefetch")
            if isinstance(np_, ast.Constant) and isinstance(np_.value, int):
                self.prefetch = np_.value

        if isinstance(grid, (ast.Tuple, ast.List)):
            self.grid_rank = len(grid.elts)

        out_shape = _kwarg(call, "out_shape")
        if isinstance(out_shape, ast.Call):
            full = ctx.resolve(out_shape.func) or ""
            if full.split(".")[-1] == "ShapeDtypeStruct" and out_shape.args:
                shp = out_shape.args[0]
                if isinstance(shp, (ast.Tuple, ast.List)):
                    self.out_rank = len(shp.elts)

        self.specs = [s for s in spec_nodes
                      if _is_call_to(ctx, s, _BLOCK_SPEC)]
        self.out_specs = [s for s in
                          _spec_exprs(_kwarg(call, "out_specs"))
                          + _spec_exprs(_kwarg(call, "out_spec"))
                          if _is_call_to(ctx, s, _BLOCK_SPEC)]

    @staticmethod
    def _resolve_grid_spec(node: ast.AST, scope: Optional[ast.AST],
                           ctx: ModuleContext) -> Optional[ast.Call]:
        if _is_call_to(ctx, node, _GRID_SPEC):
            return node
        if isinstance(node, ast.Name) and scope is not None:
            for sub in ast.walk(scope):
                if isinstance(sub, ast.Assign) \
                        and any(isinstance(t, ast.Name) and t.id == node.id
                                for t in sub.targets) \
                        and _is_call_to(ctx, sub.value, _GRID_SPEC):
                    return sub.value
        return None


def _enclosing_function(tree: ast.Module, node: ast.AST
                        ) -> Optional[ast.AST]:
    best = None
    for fn in ast.walk(tree):
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and fn.lineno <= node.lineno <= getattr(
                    fn, "end_lineno", fn.lineno):
            if best is None or fn.lineno >= best.lineno:
                best = fn
    return best


@register
class Pal001(Rule):
    rule_id = "PAL001"
    title = "Pallas grid/BlockSpec inconsistency"
    motivation = ("PR 3 Mosaic port: index_map arity and block-rank "
                  "mismatches are late TPU-only compile errors, and an "
                  "over-budget per-step block set OOMs VMEM on hardware "
                  "the CPU interpret tests never touch")

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        calls = [n for n in ast.walk(ctx.tree) if isinstance(n, ast.Call)]
        # grid-spec constructors consumed by a visible pallas_call are
        # analyzed through that site — don't double-report them
        consumed_specs = set()
        for node in calls:
            if not _is_call_to(ctx, node, _PALLAS_CALL):
                continue
            gs = _kwarg(node, "grid_spec")
            if gs is not None:
                inner = _Site._resolve_grid_spec(
                    gs, _enclosing_function(ctx.tree, node), ctx)
                if inner is not None:
                    consumed_specs.add(id(inner))
        for node in calls:
            is_pc = _is_call_to(ctx, node, _PALLAS_CALL)
            if not is_pc and not (_is_call_to(ctx, node, _GRID_SPEC)
                                  and id(node) not in consumed_specs):
                continue
            scope = _enclosing_function(ctx.tree, node)
            site = _Site(node, scope, ctx)
            yield from self._check_site(ctx, site)

    def _check_site(self, ctx: ModuleContext, site: _Site
                    ) -> Iterable[Finding]:
        env = _local_env(ctx, site.scope)
        want_arity = None
        if site.grid_rank is not None:
            want_arity = site.grid_rank + site.prefetch

        vmem_total, vmem_complete = 0, bool(site.specs)
        itemsize = ctx.config["default_itemsize"]
        for spec in site.specs:
            shape = spec.args[0] if spec.args else _kwarg(spec, "block_shape")
            idx = spec.args[1] if len(spec.args) > 1 \
                else _kwarg(spec, "index_map")
            rank = len(shape.elts) \
                if isinstance(shape, (ast.Tuple, ast.List)) else None

            info = _index_fn(site.scope, idx) if idx is not None else None
            if info is not None and want_arity is not None \
                    and info[0] != want_arity:
                yield self.finding(
                    ctx, spec,
                    f"BlockSpec index_map takes {info[0]} arg(s) but the "
                    f"grid supplies {want_arity} (grid rank "
                    f"{site.grid_rank} + {site.prefetch} scalar-prefetch "
                    f"ref(s)) — Mosaic rejects this at TPU compile time "
                    f"only")
            if info is not None and rank is not None \
                    and info[1] is not None and info[1] != rank:
                yield self.finding(
                    ctx, spec,
                    f"BlockSpec block_shape has rank {rank} but its "
                    f"index_map returns {info[1]} coordinate(s) — one "
                    f"block coordinate per block dimension")

            folded = const_int_tuple(shape, env) \
                if isinstance(shape, (ast.Tuple, ast.List)) else None
            if folded is None:
                vmem_complete = False
            else:
                n = 1
                for d in folded:
                    n *= d
                vmem_total += n * itemsize

        if site.out_rank is not None:
            for spec in site.out_specs:
                shape = spec.args[0] if spec.args \
                    else _kwarg(spec, "block_shape")
                if isinstance(shape, (ast.Tuple, ast.List)) \
                        and len(shape.elts) != site.out_rank:
                    yield self.finding(
                        ctx, spec,
                        f"out_specs block_shape rank {len(shape.elts)} != "
                        f"out_shape rank {site.out_rank}")

        budget = ctx.config["vmem_budget"]
        if vmem_complete and vmem_total > budget:
            yield self.finding(
                ctx, site.call,
                f"per-grid-step block footprint {vmem_total} bytes "
                f"exceeds the VMEM budget {budget} (≈16 MB/core minus "
                f"double-buffering headroom) — shrink block shapes or "
                f"raise --vmem-budget deliberately")


# ---------------------------------------------------------------------------
# PAL002 — cost_estimate provenance
# ---------------------------------------------------------------------------

def _local_call_graph(tree: ast.Module) -> Dict[str, Set[str]]:
    """name -> module-local function names it calls (one level)."""
    local = {n.name for n in ast.walk(tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    graph: Dict[str, Set[str]] = {}
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        calls = set()
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Call):
                name = dotted_name(sub.func)
                if name in local:
                    calls.add(name)
        graph[fn.name] = calls
    return graph


def _transitive(graph: Dict[str, Set[str]], roots: Set[str]) -> Set[str]:
    seen, todo = set(roots), list(roots)
    while todo:
        for nxt in graph.get(todo.pop(), ()):
            if nxt not in seen:
                seen.add(nxt)
                todo.append(nxt)
    return seen


def _producer(scope: Optional[ast.AST], node: Optional[ast.AST],
              local: Set[str]) -> Optional[Tuple[str, Set[str]]]:
    """For an expression (or Name assigned in ``scope``): the set of
    module-local functions called in it.  Returns (kind, names) where
    kind is 'call' when at least one local call is present, 'literal'
    when the value is fully visible with NO local calls, None when the
    value's origin is not visible (parameter, import, attribute)."""
    if node is None or scope is None:
        return None
    if isinstance(node, ast.Name):
        target = None
        for sub in ast.walk(scope):
            if isinstance(sub, ast.Assign):
                names = []
                for t in sub.targets:
                    names.extend(
                        n.id for n in ast.walk(t)
                        if isinstance(n, ast.Name))
                if node.id in names:
                    target = sub.value
        if target is None:
            return None                       # parameter / nonlocal
        node = target
    calls = {dotted_name(sub.func) for sub in ast.walk(node)
             if isinstance(sub, ast.Call)}
    local_calls = {c for c in calls if c in local}
    if local_calls:
        return ("call", local_calls)
    # only call it a literal when no opaque (non-local) calls other than
    # plain constructors are involved — pl.CostEstimate(1, 2, 3) counts
    opaque = {c for c in calls
              if c and not c.endswith("CostEstimate") and c not in local}
    if opaque:
        return None
    return ("literal", set())


@register
class Pal002(Rule):
    rule_id = "PAL002"
    title = "cost_estimate not derived from the spec plan"
    motivation = ("paged_attention's one-source-of-truth fix: the "
                  "advertised CostEstimate.bytes_accessed steers "
                  "cost-model placement, so a cost built apart from the "
                  "BlockSpec plan silently drifts the moment a block "
                  "shape changes")

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        local = {n.name for n in ast.walk(ctx.tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        graph = _local_call_graph(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call) \
                    or not _is_call_to(ctx, node, _PALLAS_CALL):
                continue
            cost = _kwarg(node, "cost_estimate")
            if cost is None:
                continue
            scope = _enclosing_function(ctx.tree, node)
            # which local helper produced the specs?
            spec_src = self._spec_producer(ctx, node, scope, local)
            if spec_src is None:
                continue                       # specs inline or opaque
            cost_src = _producer(scope, cost, local)
            if cost_src is None:
                continue                       # cost origin not visible
            kind, cost_calls = cost_src
            reach = _transitive(graph, cost_calls)
            if spec_src in reach:
                continue                       # derived from the plan
            yield self.finding(
                ctx, cost,
                f"cost_estimate is "
                f"{'a literal' if kind == 'literal' else 'built from ' + ', '.join(sorted(cost_calls))} "
                f"but in_specs come from `{spec_src}(...)` — derive the "
                f"cost by calling the same plan helper so bytes_accessed "
                f"cannot drift from the BlockSpecs")

    @staticmethod
    def _spec_producer(ctx: ModuleContext, call: ast.Call,
                       scope: Optional[ast.AST],
                       local: Set[str]) -> Optional[str]:
        """The module-local function whose (possibly tuple-unpacked)
        result supplies in_specs — via the call's in_specs kwarg or its
        grid_spec's."""
        node = _kwarg(call, "in_specs")
        if node is None:
            gs = _Site._resolve_grid_spec(
                _kwarg(call, "grid_spec"), scope, ctx) \
                if _kwarg(call, "grid_spec") is not None else None
            if gs is not None:
                node = _kwarg(gs, "in_specs")
        if not isinstance(node, ast.Name) or scope is None:
            return None
        for sub in ast.walk(scope):
            if not isinstance(sub, ast.Assign) \
                    or not isinstance(sub.value, ast.Call):
                continue
            names = []
            for t in sub.targets:
                names.extend(n.id for n in ast.walk(t)
                             if isinstance(n, ast.Name))
            if node.id in names:
                fname = dotted_name(sub.value.func)
                if fname in local:
                    return fname
        return None
