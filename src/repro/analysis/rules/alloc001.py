"""ALLOC001 — ignored return of BlockAllocator.free().

Since PR 5 (prefix-sharing, copy-on-write), ``free()`` returns the
sublist of blocks whose refcount actually hit zero — shared pages stay
alive.  Callers that drop the return can't scrub or recycle the right
pages: the engine zeroes exactly the physically-freed blocks before
reuse, and the fleet's page accounting reconciles against that list.
A bare ``allocator.free(blocks)`` statement is therefore either a
leak-adjacent bug or (in tests that only exercise refcounts) needs an
explicit suppression.

Heuristic: any expression-statement call whose callee leaf is ``free``
on a receiver whose name suggests the block allocator (``alloc`` /
``allocator`` stem, or a bare ``a``/``ba`` in tests constructed from
``BlockAllocator``).  We keep it name-based — static typing isn't
available — but require the module to reference ``BlockAllocator``
somewhere, so unrelated ``free()`` APIs don't trip it.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.core import Finding, ModuleContext, Rule, register


def _mentions_block_allocator(ctx: ModuleContext) -> bool:
    if "BlockAllocator" in ctx.text:
        return True
    return any(full.endswith("BlockAllocator")
               for full in ctx.imports.names.values())


@register
class Alloc001(Rule):
    rule_id = "ALLOC001"
    title = "BlockAllocator.free() return value ignored"
    motivation = ("PR 5 copy-on-write pages: free() returns only the "
                  "physically-freed sublist (shared pages survive); the "
                  "engine scrubs exactly that list before reuse, so "
                  "dropping it desyncs page scrubbing from the refcount "
                  "ledger")

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        if not _mentions_block_allocator(ctx):
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Expr) \
                    or not isinstance(node.value, ast.Call):
                continue
            call = node.value
            if not (isinstance(call.func, ast.Attribute)
                    and call.func.attr == "free"):
                continue
            yield self.finding(
                ctx, call,
                "return value of BlockAllocator.free() ignored — it is "
                "the physically-freed sublist (refcounted pages may "
                "survive); consume it to scrub/recycle the right pages, "
                "or suppress if only refcounts are under test")
