"""Rule modules register themselves on import (``@register``)."""
from repro.analysis.rules import (alloc001, det001, hot001, jit001,  # noqa
                                  pal001)
