"""HOT001 — per-element device dispatch inside host-side Python loops.

PR 2's admission-path lesson: one `jnp` op per slot inside a Python
``for`` costs a dispatch (and on real backends a host→device transfer)
per iteration, turning an O(1) tick into O(slots).  The fix is always
the same — assemble operands in numpy inside the loop, convert once
outside it.  This rule flags ``jnp.*`` calls and ``.at[...].set/add``
functional updates lexically inside ``for``/``while`` bodies in
host-side ``serve/`` code (the engine/router/broker plane; jitted
kernels and traced model code legitimately loop over jnp ops — Python
loops there unroll at trace time, once).
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.core import (Finding, ModuleContext, Rule, dotted_name,
                                 register)

SCOPES = ("src/repro/serve/",)

_AT_METHODS = {"set", "add", "multiply", "divide", "power", "min", "max",
               "get", "apply"}


def _is_at_update(node: ast.Call) -> bool:
    """x.at[idx].set(...) — functional index update on any array."""
    f = node.func
    return (isinstance(f, ast.Attribute) and f.attr in _AT_METHODS
            and isinstance(f.value, ast.Subscript)
            and isinstance(f.value.value, ast.Attribute)
            and f.value.value.attr == "at")


def _jnp_roots(ctx: ModuleContext) -> List[str]:
    """Local names bound to jax.numpy (usually just 'jnp')."""
    return [name for name, full in ctx.imports.names.items()
            if full in ("jax.numpy", "jnp")]


@register
class Hot001(Rule):
    rule_id = "HOT001"
    title = "per-element device dispatch in a host loop"
    motivation = ("PR 2 decode-path optimisation: per-slot jnp ops in the "
                  "admission loop made tick cost O(slots); batching to "
                  "one conversion per tick was the whole win")

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        if not ctx.rel.startswith(SCOPES):
            return
        roots = _jnp_roots(ctx)
        # walk loops at module+function level; anything lexically inside
        # a for/while body is host-loop code in serve/ (no tracing there)
        for loop in ast.walk(ctx.tree):
            if not isinstance(loop, (ast.For, ast.AsyncFor, ast.While)):
                continue
            for node in ast.walk(loop):
                if node is loop or not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func)
                if name and roots and name.split(".")[0] in roots:
                    yield self.finding(
                        ctx, node,
                        f"`{name}(...)` inside a host-side Python loop "
                        f"dispatches one device op per iteration — build "
                        f"the operand in numpy inside the loop and "
                        f"convert once after it (PR 2 O(slots) tick "
                        f"regression)")
                elif _is_at_update(node):
                    yield self.finding(
                        ctx, node,
                        "`.at[...]."
                        f"{node.func.attr}(...)` inside a host-side "
                        "Python loop copies the whole array per "
                        "iteration — accumulate indices/values and apply "
                        "one batched update after the loop")
