"""DET001 — unseeded nondeterminism in replay-critical code.

Every PR since PR 2 asserts that failover/chaos survivors are
bitwise-equal to a calm run; the broker's heartbeat process and the
fault plane are deterministic ONLY because every stochastic decision
draws from a seeded stream (``np.random.RandomState(seed)``,
counter-based per-slot PRNG keys).  One module-level ``np.random.*``
call, one stdlib ``random.*`` draw, or one wall-clock read
(``time.time()`` / ``datetime.now()``) inside ``core/`` / ``serve/`` /
``models/`` / ``kernels/`` silently breaks replay for the whole fleet.

Allowed: constructing seeded generators (``RandomState``,
``default_rng``, ``Generator``, ``SeedSequence``, bit generators) and
everything under ``jax.random`` (explicit-key API — keys are data, not
hidden state).
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.core import Finding, ModuleContext, Rule, register

SCOPES = ("src/repro/core/", "src/repro/serve/", "src/repro/models/",
          "src/repro/kernels/")

# numpy.random names that construct SEEDED generators (allowed)
_SEEDED = {"RandomState", "default_rng", "Generator", "SeedSequence",
           "BitGenerator", "PCG64", "PCG64DXSM", "Philox", "MT19937",
           "SFC64"}
# stdlib random names that construct seedable generators (allowed)
_RANDOM_OK = {"Random", "SystemRandom", "getstate", "setstate"}
# wall-clock reads (each one a replay divergence)
_CLOCKS = {"time.time", "time.time_ns", "time.monotonic",
           "time.monotonic_ns", "time.perf_counter",
           "time.perf_counter_ns", "datetime.datetime.now",
           "datetime.datetime.utcnow", "datetime.datetime.today",
           "datetime.date.today"}


@register
class Det001(Rule):
    rule_id = "DET001"
    title = "unseeded nondeterminism in replay-critical code"
    motivation = ("bitwise-deterministic replay: PR 4/5/6 failover and "
                  "chaos benches assert survivors are bitwise-equal to a "
                  "calm run — one hidden-state draw breaks the assert "
                  "fleet-wide")

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        if not ctx.rel.startswith(SCOPES):
            return
        from repro.analysis.core import dotted_name
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            raw = dotted_name(node.func)
            # only trust names that actually came through an import —
            # a local variable that happens to be called `random` is not
            # the stdlib module
            if not raw or raw.split(".")[0] not in ctx.imports.names:
                continue
            full = ctx.imports.resolve(raw)
            leaf = full.rsplit(".", 1)[-1]
            if full.startswith("numpy.random.") and leaf not in _SEEDED:
                yield self.finding(
                    ctx, node,
                    f"module-level numpy.random call `{full}` draws from "
                    f"hidden global state — use a seeded "
                    f"np.random.RandomState/default_rng so replay stays "
                    f"bitwise-deterministic")
            elif full.startswith("random.") and full.count(".") == 1 \
                    and leaf not in _RANDOM_OK:
                yield self.finding(
                    ctx, node,
                    f"stdlib `{full}` draws from hidden global state — "
                    f"use a seeded random.Random(seed) (or numpy "
                    f"RandomState) so replay stays bitwise-deterministic")
            elif full in _CLOCKS:
                yield self.finding(
                    ctx, node,
                    f"wall-clock read `{full}()` in replay-critical code "
                    f"— thread a tick counter / seeded schedule through "
                    f"instead (calm-vs-fault replay must not depend on "
                    f"real time)")
