"""JIT001 — read of a donated buffer before rebinding.

``jax.jit(..., donate_argnums=...)`` invalidates the caller's reference:
on accelerator backends the buffer is reused for the output, so a later
read of the SAME Python name returns garbage (or raises) — but only off
CPU, which is exactly why runtime tests on this container never catch
it.  The engine's tick/admit path donates the cache and seen-mask
pytrees into every jitted step (``serve/engine.py``); the invariant is
that a name passed in a donated position is DEAD until rebound, and the
step's own result assignment is the only thing that revives it.

The pass is intra-function and deliberately simple: it resolves
``jax.jit`` bindings (direct ``donate_argnums=`` kwargs, ``**kw`` dicts
built with ``dict(donate_argnums=...)`` anywhere in the module — the
engine's conditional ``dn = dict(...) if donate else {}`` pattern counts
as donating, because it DOES donate on the backends that matter — and
``@partial(jax.jit, donate_argnums=...)`` decorators), then walks each
function body in source order tracking consumed names.  Branches union
(a name possibly donated on SOME path is unsafe), loop bodies get a
second pass so a consume at the bottom of a loop poisons a read at the
top (the tick-loop hazard class).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import (Finding, ModuleContext, Rule, dotted_name,
                                 register)

_JIT_NAMES = {"jax.jit", "jax.api.jit", "jax.pjit", "jax.experimental.pjit"}


def _donate_positions(call: ast.Call, module: ast.Module) -> Set[int]:
    """Donated argnums of a jit(...) call node, following ``**name``
    kwargs to ``name = dict(donate_argnums=...)`` assignments anywhere
    in the module (conditional dicts count — they donate on accelerator
    backends)."""
    out: Set[int] = set()

    def from_value(value: ast.AST) -> None:
        if isinstance(value, ast.Constant) and isinstance(value.value, int):
            out.add(value.value)
        elif isinstance(value, (ast.Tuple, ast.List)):
            for el in value.elts:
                if isinstance(el, ast.Constant) \
                        and isinstance(el.value, int):
                    out.add(el.value)

    def scan_kwargs(kwargs) -> None:
        for kw in kwargs:
            if kw.arg == "donate_argnums":
                from_value(kw.value)
            elif kw.arg is None and isinstance(kw.value, ast.Name):
                # **dn — find dict(donate_argnums=...) assigned to dn
                for node in ast.walk(module):
                    if isinstance(node, ast.Assign) and any(
                            isinstance(t, ast.Name)
                            and t.id == kw.value.id
                            for t in node.targets):
                        for sub in ast.walk(node.value):
                            if isinstance(sub, ast.Call) \
                                    and isinstance(sub.func, ast.Name) \
                                    and sub.func.id == "dict":
                                scan_kwargs(sub.keywords)

    scan_kwargs(call.keywords)
    return out


def _jit_call(node: ast.AST, ctx: ModuleContext) -> Optional[ast.Call]:
    """The jit(...) Call if ``node`` is one (directly or via
    functools.partial(jax.jit, ...))."""
    if not isinstance(node, ast.Call):
        return None
    full = ctx.resolve(node.func)
    if full in _JIT_NAMES:
        return node
    if full in ("functools.partial", "partial") and node.args:
        inner = ctx.resolve(node.args[0])
        if inner in _JIT_NAMES:
            return node
    return None


def _collect_donating(ctx: ModuleContext) -> Dict[str, Set[int]]:
    """Dotted callable name -> donated positions, module-wide.  Covers
    ``self._step = jax.jit(f, donate_argnums=...)`` assignments and
    ``@partial(jax.jit, donate_argnums=...)`` decorated defs."""
    table: Dict[str, Set[int]] = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assign):
            call = _jit_call(node.value, ctx)
            if call is None:
                continue
            pos = _donate_positions(call, ctx.tree)
            if not pos:
                continue
            for t in node.targets:
                name = dotted_name(t)
                if name:
                    table[name] = pos
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                call = _jit_call(dec, ctx)
                if call is not None:
                    pos = _donate_positions(call, ctx.tree)
                    if pos:
                        table[node.name] = pos
    return table


def _reads(node: ast.AST) -> List[Tuple[str, ast.AST]]:
    """All dotted names loaded in an expression/statement (longest
    attribute chains only)."""
    out: List[Tuple[str, ast.AST]] = []
    for sub in ast.walk(node):
        if isinstance(sub, (ast.Name, ast.Attribute)) \
                and isinstance(getattr(sub, "ctx", None), ast.Load):
            name = dotted_name(sub)
            if name:
                out.append((name, sub))
    # keep only maximal chains (self.caches.shape reported once, and a
    # prefix match against consumed names still catches self.caches)
    maximal = []
    names = [n for n, _ in out]
    for name, sub in out:
        if not any(other != name and other.startswith(name + ".")
                   for other in names):
            maximal.append((name, sub))
    return maximal


def _touches(read: str, consumed: str) -> bool:
    return read == consumed or read.startswith(consumed + ".")


class _Scope:
    """Linear walk of one function body tracking donated-and-dead
    names: dotted name -> line where it was consumed."""

    def __init__(self, rule: "Jit001", ctx: ModuleContext,
                 donating: Dict[str, Set[int]]):
        self.rule = rule
        self.ctx = ctx
        self.donating = donating
        self.findings: List[Finding] = []
        self._seen: Set[Tuple[int, int, str]] = set()

    # -- helpers ---------------------------------------------------------

    def _flag(self, node: ast.AST, name: str, consumed_line: int) -> None:
        key = (node.lineno, node.col_offset, name)
        if key in self._seen:
            return
        self._seen.add(key)
        self.findings.append(self.rule.finding(
            self.ctx, node,
            f"`{name}` was passed in a donated position on line "
            f"{consumed_line} and read again before rebinding — the "
            f"buffer is dead after the jitted call (off-CPU this reads "
            f"freed memory); rebind it from the call's result first"))

    def _check_reads(self, node: ast.AST, consumed: Dict[str, int]) -> None:
        if not consumed:
            return
        for name, sub in _reads(node):
            for dead, line in consumed.items():
                if _touches(name, dead):
                    self._flag(sub, dead, line)

    def _consume(self, node: ast.AST, consumed: Dict[str, int]) -> None:
        """Mark donated args of any donating call inside ``node``."""
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            callee = dotted_name(sub.func)
            pos = self.donating.get(callee or "")
            if not pos:
                continue
            for i, arg in enumerate(sub.args):
                if i in pos:
                    name = dotted_name(arg)
                    if name:
                        consumed[name] = sub.lineno

    def _rebind(self, target: ast.AST, consumed: Dict[str, int]) -> None:
        for sub in ast.walk(target):
            if isinstance(sub, (ast.Name, ast.Attribute)):
                name = dotted_name(sub)
                if name:
                    for dead in [d for d in consumed
                                 if _touches(d, name) or _touches(name, d)]:
                        del consumed[dead]

    # -- statement walk --------------------------------------------------

    def walk(self, stmts, consumed: Dict[str, int]) -> Dict[str, int]:
        for stmt in stmts:
            consumed = self._stmt(stmt, consumed)
        return consumed

    def _stmt(self, stmt: ast.stmt, consumed: Dict[str, int]
              ) -> Dict[str, int]:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return consumed                    # separate scope
        if isinstance(stmt, ast.Assign):
            self._check_reads(stmt.value, consumed)
            self._consume(stmt.value, consumed)
            for t in stmt.targets:
                self._rebind(t, consumed)
            return consumed
        if isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            if stmt.value is not None:
                self._check_reads(stmt.value, consumed)
                self._consume(stmt.value, consumed)
            if isinstance(stmt, ast.AugAssign):
                self._check_reads(stmt.target, consumed)
            self._rebind(stmt.target, consumed)
            return consumed
        if isinstance(stmt, ast.If):
            self._check_reads(stmt.test, consumed)
            self._consume(stmt.test, consumed)
            a = self.walk(stmt.body, dict(consumed))
            b = self.walk(stmt.orelse, dict(consumed))
            return {**b, **a}                  # may-be-donated union
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._check_reads(stmt.iter, consumed)
            self._consume(stmt.iter, consumed)
            self._rebind(stmt.target, consumed)
            once = self.walk(stmt.body, dict(consumed))
            # second pass: a consume at the bottom of the body reaches a
            # read at the top on the next iteration
            twice = self.walk(stmt.body, dict(once))
            out = {**consumed, **once, **twice}
            return self.walk(stmt.orelse, out)
        if isinstance(stmt, ast.While):
            self._check_reads(stmt.test, consumed)
            once = self.walk(stmt.body, dict(consumed))
            self._check_reads(stmt.test, once)
            twice = self.walk(stmt.body, dict(once))
            out = {**consumed, **once, **twice}
            return self.walk(stmt.orelse, out)
        if isinstance(stmt, ast.With):
            for item in stmt.items:
                self._check_reads(item.context_expr, consumed)
                self._consume(item.context_expr, consumed)
                if item.optional_vars is not None:
                    self._rebind(item.optional_vars, consumed)
            return self.walk(stmt.body, consumed)
        if isinstance(stmt, ast.Try):
            consumed = self.walk(stmt.body, consumed)
            for h in stmt.handlers:
                consumed = self.walk(h.body, dict(consumed))
            consumed = self.walk(stmt.orelse, consumed)
            return self.walk(stmt.finalbody, consumed)
        if isinstance(stmt, ast.Delete):
            for t in stmt.targets:
                self._rebind(t, consumed)
            return consumed
        # Expr / Return / Assert / Raise / ...
        self._check_reads(stmt, consumed)
        self._consume(stmt, consumed)
        return consumed


@register
class Jit001(Rule):
    rule_id = "JIT001"
    title = "donated buffer read before rebinding"
    motivation = ("PR 1 donation of the slot-cache pytree into "
                  "make_engine_step: a stale read after the donated tick "
                  "call is invisible on this CPU container (donation is "
                  "a no-op there) and corrupts memory on every real "
                  "accelerator")

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        donating = _collect_donating(ctx)
        if not donating:
            return
        for fn in ctx.functions():
            scope = _Scope(self, ctx, donating)
            scope.walk(fn.body, {})
            yield from scope.findings
