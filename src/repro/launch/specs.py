"""ShapeDtypeStruct stand-ins for every model input — the dry-run lowers
against these (weak-type-correct, shardable, zero allocation).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, InputShape, get_config
from repro.models.config import ModelConfig
from repro.models.transformer import init_cache, init_params
from repro.optim.adamw import adamw

SDS = jax.ShapeDtypeStruct


def batch_sds(cfg: ModelConfig, batch: int, seq: int, *, kind: str) -> Dict:
    """Training/prefill batches carry (B, S); decode carries (B, 1).
    VLM/audio backbones receive stub frontend embeddings for the prompt
    (prefill/train) and token ids during decode."""
    if kind == "decode":
        return {"tokens": SDS((batch, 1), jnp.int32)}
    out: Dict = {}
    if cfg.ext_embed_dim:
        out["embeds"] = SDS((batch, seq, cfg.ext_embed_dim), jnp.float32)
    else:
        out["tokens"] = SDS((batch, seq), jnp.int32)
    if kind == "train":
        out["labels"] = SDS((batch, seq), jnp.int32)
    return out


def params_sds(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.random.PRNGKey(0))


def opt_state_sds(cfg: ModelConfig, optimizer=None):
    import os
    bits = int(os.environ.get("REPRO_OPT_BITS", "32"))
    opt = optimizer or adamw(1e-4, state_bits=bits)
    p = params_sds(cfg)
    return jax.eval_shape(opt.init, p)


def caches_sds(cfg: ModelConfig, batch: int, cache_len: int, *,
               paged: bool = False, page_size: int = 16):
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, cache_len, paged=paged,
                           page_size=page_size))


def positions_sds(batch: int, seq: int):
    return SDS((batch, seq), jnp.int32)


def block_table_sds(batch: int, cache_len: int, page_size: int):
    """(slots, n_cols) int32 block table for the paged engine step."""
    return SDS((batch, max(1, -(-cache_len // page_size))), jnp.int32)


def sampling_sds(cfg: ModelConfig, batch: int) -> Dict:
    """Per-slot sampling operands of the engine step: counter-based PRNG
    key data, temperature / top-p / top-k / repetition-penalty vectors,
    and the (slots, vocab) seen-token mask the penalty reads."""
    return {"rng_keys": SDS((batch, 2), jnp.uint32),
            "temperature": SDS((batch,), jnp.float32),
            "top_p": SDS((batch,), jnp.float32),
            "top_k": SDS((batch,), jnp.int32),
            "rep_penalty": SDS((batch,), jnp.float32),
            "seen": SDS((batch, cfg.vocab_size), jnp.bool_)}


def input_specs(arch: str, shape_name: str, *, paged: bool = False,
                page_size: int = 16) -> Dict:
    """All dry-run inputs for one (architecture, input-shape) pair.

    train  -> {params, opt_state, batch}
    prefill-> {params, caches, batch, positions}
    decode -> {params, caches, batch, positions}  (batch = one token)

    ``paged=True`` (decode only) swaps the dense caches for block pools
    and adds the engine-step operands: ``table`` plus the per-slot
    sampling vectors (see ``repro.serve.engine.make_engine_step``).
    """
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    out = {"cfg": cfg, "shape": shape, "params": params_sds(cfg)}
    if shape.kind == "train":
        out["opt_state"] = opt_state_sds(cfg)
        out["batch"] = batch_sds(cfg, B, S, kind="train")
    elif shape.kind == "prefill":
        out["caches"] = caches_sds(cfg, B, S)
        out["batch"] = batch_sds(cfg, B, S, kind="prefill")
        out["positions"] = positions_sds(B, S)
    else:  # decode: one new token against a seq_len cache
        out["caches"] = caches_sds(cfg, B, S, paged=paged,
                                   page_size=page_size)
        out["batch"] = batch_sds(cfg, B, 1, kind="decode")
        out["positions"] = positions_sds(B, 1)
        if paged:
            out["table"] = block_table_sds(B, S, page_size)
            out["sampling"] = sampling_sds(cfg, B)
    return out
