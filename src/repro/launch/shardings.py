"""GSPMD sharding rules for every parameter / optimizer / batch / cache
leaf in the system (DESIGN.md §5).

Scheme: 2D — FSDP-style sharding of the contraction dim on ``data``,
tensor parallelism of head/ffn/expert dims on ``model``; batch on
(pod, data); MoE experts on ``model`` (expert parallelism); decode caches
shard kv-heads (or MLA latent / SSM state) on ``model`` and batch on
``data``, except long-context batch=1 where the *sequence* dim of caches
shards on ``data``.

Rules are keyed on leaf path names; every leaf gets an explicit rule
(unknown names raise, so new params can't silently replicate).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


# --- name-keyed rules: value = spec WITHOUT the stack period axis ---------
# dp = data axes tuple (e.g. ("data",)); mp = "model"

def _param_rules(dp, mp):
    dps = dp if len(dp) > 1 else dp[0] if dp else None
    return {
        # norms / small vectors: replicated
        "norm1": P(), "norm2": P(), "final_norm": P(), "norm": P(),
        "q_norm": P(), "k_norm": P(), "kv_norm": P(), "ln_x": P(),
        "norm_h": P(), "norm_e": P(),
        "mu": P(None, None), "conv_b": P(), "dt_bias": P(), "D": P(),
        "w0": P(mp), "u": P(mp, None),
        # embeddings / head
        # vocab on model, d replicated: keeps the CE-loss contraction local
        # (d-on-data head sharding partial-sums (tokens, V/16) f32 logits)
        "embed": P(mp, None), "lm_head": P(None, mp), "ext_proj": P(None, mp),
        # attention
        "wq": P(dps, mp), "wk": P(dps, mp), "wv": P(dps, mp),
        "wo": P(mp, dps),
        "bq": P(mp), "bk": P(mp), "bv": P(mp),
        # MLA
        "wq_a": P(dps, None), "wq_b": P(None, mp),
        "wkv_a": P(dps, None), "wk_b": P(None, mp), "wv_b": P(None, mp),
        # dense ffn (2D) / moe experts (3D) share names; see _spec_for
        "w_gate": P(dps, mp), "w_up": P(dps, mp), "w_down": P(mp, dps),
        "router": P(dps, None),
        # mamba
        "in_proj": P(dps, mp), "conv_w": P(None, mp),
        "x_proj": P(mp, None), "dt_proj": P(None, mp),
        "A_log": P(mp, None),
        "out_proj": P(mp, dps),
        # rwkv
        "wr": P(dps, mp), "wg": P(dps, mp),
        "wA": P(dps, None), "wB": P(None, mp),
        # mtp projector
        "proj": P(dps, None),
    }


def _moe_rules(dp, mp):
    dps = dp if len(dp) > 1 else dp[0] if dp else None
    return {
        "w_gate": P(mp, dps, None), "w_up": P(mp, dps, None),
        "w_down": P(mp, None, dps),
    }


def _path_names(path) -> list:
    return [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]


def param_specs(params, mesh) -> dict:
    """Pytree of PartitionSpec matching ``params`` (shapes from SDS or
    arrays)."""
    from repro.launch.mesh import data_axes, model_axis
    dp, mp = data_axes(mesh), model_axis(mesh)
    rules = _param_rules(dp, mp)
    moe_rules = _moe_rules(dp, mp)

    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[-1]
        in_stack = "stack" in names
        ndim = len(leaf.shape)
        if name in moe_rules and ndim - (1 if in_stack else 0) == 3:
            spec = moe_rules[name]
        elif name in rules:
            spec = rules[name]
        else:
            raise KeyError(f"no sharding rule for param {'/'.join(names)} "
                           f"shape={leaf.shape}")
        base = len(spec)
        want = ndim - (1 if in_stack else 0)
        if base < want:                       # e.g. P() for any-rank norms
            spec = P(*(tuple(spec) + (None,) * (want - base)))
        if in_stack:
            spec = P(*((None,) + tuple(spec)))
        return spec

    return jax.tree_util.tree_map_with_path(spec_for, params)


def opt_specs(opt_state, p_specs, mesh) -> dict:
    """Optimizer state: moments/master shard like params; counters
    replicate."""
    def build(st):
        out = {}
        for k, v in st.items():
            if k in ("mu", "nu", "master", "vel"):
                out[k] = p_specs
            else:
                out[k] = P()
        return out
    return build(opt_state)


def batch_specs(batch, mesh, *, shard_batch: bool = True) -> dict:
    from repro.launch.mesh import data_axes
    dp = data_axes(mesh)
    dps = dp if len(dp) > 1 else dp[0]

    def spec_for(path, leaf):
        b = dps if shard_batch else None
        return P(*((b,) + (None,) * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(spec_for, batch)


def cache_leaf_spec(name: str, *, long_ctx: bool, dp, mp,
                    shape=None, mp_size: int = 0) -> P:
    """Per-leaf cache sharding (shape WITHOUT the stack period axis).

    KV caches prefer sharding kv-heads on ``model``; when the head count
    doesn\'t divide the axis (e.g. 8 heads on 16 ranks) they shard head_dim
    instead — otherwise GSPMD re-shards internally and pays a full-cache
    gather at every pinned cache update.

    The same name-keyed rules cover PAGED pools (k/v: (N, page, Hkv, hd),
    pos: (N, page), ckv/krope: (N, page, kr|dr)): ranks match the dense
    layouts with the block axis standing in for batch, so blocks shard on
    ``data`` and heads/latent on ``model`` — block-table gathers then move
    pages over data, which the dry-run compiles as the paging a2a cost."""
    dps = dp if len(dp) > 1 else dp[0]
    bspec = None if long_ctx else dps
    seq = dps if long_ctx else None
    kv_spec = P(bspec, seq, mp, None)
    if shape is not None and mp_size and len(shape) == 4:
        if shape[2] % mp_size != 0 and shape[3] % mp_size == 0:
            kv_spec = P(bspec, seq, None, mp)
    table = {
        "k": kv_spec,                          # (B, T, Hkv, hd)
        "v": kv_spec,
        "pos": P(bspec, seq),                  # (B, T)
        "ckv": P(bspec, seq, mp),              # (B, T, kr)
        "krope": P(bspec, seq, mp),            # (B, T, dr)
        "h": P(bspec, mp, None),               # mamba (B, di, ds)
        "conv": P(bspec, None, mp),            # (B, K-1, di)
        "state": P(bspec, mp, None, None),     # rwkv (B, H, hd, hd)
        "shift": P(bspec, None),               # (B, d)
    }
    if name not in table:
        raise KeyError(f"no cache rule for {name}")
    return table[name]


def cache_specs(caches, mesh, *, batch_size: int) -> dict:
    """Decode caches.  Normal: batch on data, heads/state on model.
    batch=1 long-context: sequence dim on data instead."""
    from repro.launch.mesh import data_axes, model_axis
    dp, mp = data_axes(mesh), model_axis(mesh)
    long_ctx = batch_size == 1

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def spec_for(path, leaf):
        names = _path_names(path)
        in_stack = "stack" in names
        shape = leaf.shape[1:] if in_stack else leaf.shape
        spec = cache_leaf_spec(names[-1], long_ctx=long_ctx, dp=dp, mp=mp,
                               shape=shape, mp_size=sizes[mp])
        assert len(spec) == len(shape), (names, leaf.shape, spec)
        if in_stack:
            spec = P(*((None,) + tuple(spec)))
        return spec

    return jax.tree_util.tree_map_with_path(spec_for, caches)


def default_hint_rule(mesh, *, batch_size: int, decode_tp: bool = False):
    """Hint rule for ``repro.models.hints``: pins cache-update outputs to
    the boundary cache sharding (kills GSPMD reshard round-trips) and
    places MoE dispatch buffers expert-parallel.

    ``decode_tp``: single-token decode steps shard the residual stream's
    hidden dim over the data axes (weight-stationary 2D TP) — otherwise
    GSPMD all-gathers every FSDP-sharded weight per decoded token (§Perf
    hillclimb C)."""
    from repro.launch.mesh import data_axes, model_axis
    dp, mp = data_axes(mesh), model_axis(mesh)
    dps = dp if len(dp) > 1 else dp[0]
    long_ctx = batch_size == 1

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def rule(kind: str, shape) -> Optional[P]:
        if kind.startswith("cache/"):
            return cache_leaf_spec(kind.split("/", 1)[1],
                                   long_ctx=long_ctx, dp=dp, mp=mp,
                                   shape=shape, mp_size=sizes[mp])
        if kind == "moe_buffer":               # (G, E, C, d)
            if decode_tp:
                # align buffer's d with the expert weights' FSDP axis so
                # the expert einsum partial-sums activations instead of
                # all-gathering 100s-of-MB weights per decoded token
                return P(None, mp, None, dps)
            return P(dps, mp, None, None)
        if kind == "moe_h":                    # (G, E, C, d)
            return None if decode_tp else P(dps, mp, None, None)
        if kind in ("moe_buffer_local", "moe_h_local"):
            return None if decode_tp else P(dps, None, None, None)
        if kind == "moe_tokens":               # (T, d)
            return None if decode_tp else P(dps, None)
        if kind == "ffn_hidden":               # (B, S, d_ff)
            # train/prefill: batch on data + hidden on model (Megatron).
            # Without the pin GSPMD replicates the batch and partial-sums
            # (B,S,d_ff) f32 activations over data — 100x the traffic of
            # the FSDP weight gathers this layout implies.
            return None if decode_tp else P(dps, None, mp)
        if kind == "residual":                 # (B, S, d)
            if decode_tp:
                return P(None, None, dps)
            return P(dps, None, None)
        if kind == "attn_q":                   # (B, S, Hq, hd)
            if decode_tp:                      # align with hd-sharded caches
                return P(None, None, None, mp)
            if len(shape) == 4 and shape[2] % sizes[mp] != 0:
                return None                    # MHA with 24/40 heads: a pin
                # sanitized to replicated-heads forces full-cache gathers
            return P(dps, None, mp, None)
        return None

    return rule


def sanitize_spec(spec: P, shape, mesh) -> P:
    """Drop mesh axes from dims they don't divide (e.g. 24 kv-heads on a
    16-way model axis -> replicate that dim).  Rank-pad with None."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= sizes[a]
        out.append(entry if dim % n == 0 else None)
    return P(*out)


def to_shardings(specs, mesh, tree=None):
    """PartitionSpec pytree -> NamedSharding pytree; if ``tree`` (arrays or
    SDS) is given, specs are sanitized against its shapes.  ``specs`` may
    be a PREFIX of ``tree`` (e.g. one spec covering the {q, s} pair of an
    int8-quantized optimizer moment): the spec broadcasts over the
    subtree, sanitized per leaf."""
    is_spec = lambda x: isinstance(x, P)
    if tree is None:
        return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                            is_leaf=is_spec)

    def per(spec, sub):
        return jax.tree.map(
            lambda t: NamedSharding(mesh, sanitize_spec(spec, t.shape, mesh)),
            sub)

    return jax.tree.map(per, specs, tree, is_leaf=is_spec)
