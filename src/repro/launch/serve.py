"""Serving launcher: batched continuous-batching engine over a smoke
config (CPU) — the production-mesh serve path is proven by dryrun.py.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b \
        --requests 8 --max-new 16 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ALL_ARCHS), default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ServingEngine(params, cfg, slots=args.slots,
                           cache_len=args.cache_len)
    key = jax.random.PRNGKey(args.seed + 1)
    for i in range(args.requests):
        key, sub = jax.random.split(key)
        prompt = jax.random.randint(sub, (4 + i % 4,), 0,
                                    cfg.vocab_size).tolist()
        engine.submit(Request(i, prompt, max_new=args.max_new))
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"{cfg.name}: served {len(done)} requests, {toks} tokens in "
          f"{dt:.2f}s ({toks/dt:.1f} tok/s, slots={args.slots})")
    for r in sorted(done, key=lambda r: r.req_id)[:4]:
        print(f"  req{r.req_id}: prompt={r.prompt} -> {r.generated}")


if __name__ == "__main__":
    main()
