"""Serving launcher: chunked-prefill continuous-batching engine over a
smoke config (CPU) — the production-mesh serve path is proven by dryrun.py.

The engine runs exactly two steady-state jitted shapes: the chunked-
prefill step ``(slots, chunk)`` and the decode tick ``(slots, 1)``;
``--warmup`` compiles both ahead of traffic and reports the compile time
separately from serving throughput.

``--paged`` (default on) stores KV through a block table: per-request
cache memory is ceil((prompt + max_new) / page_size) pages from a shared
``--num-blocks`` pool instead of one worst-case ``cache_len`` per slot,
and the queue backpressures when the pool is exhausted.  ``--no-paged``
selects the dense per-slot ring caches (bitwise reference semantics).
``--temperature``/``--top-p``/``--top-k``/``--rep-penalty`` sample
in-jit with per-slot PRNG streams (temperature 0 = greedy,
bitwise-stable; the repetition penalty reads an in-jit per-slot
seen-token mask).  ``--kernel`` decodes through the fused Pallas
paged-attention kernel (block-table-driven page DMA) instead of the
chunked-gather scan path.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b \
        --requests 8 --max-new 16 --slots 4 --chunk 16 --page-size 16
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ALL_ARCHS), default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--chunk", type=int, default=16,
                    help="prefill chunk: admission costs ceil(S/chunk) "
                         "jitted steps instead of S")
    ap.add_argument("--paged", dest="paged", action="store_true",
                    default=True,
                    help="block-table KV cache: per-request pages from a "
                         "shared pool (default)")
    ap.add_argument("--no-paged", dest="paged", action="store_false",
                    help="dense per-slot ring caches (reference semantics)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="positions per cache page (paged mode)")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="pool size in pages; 0 = same memory as the dense "
                         "cache (slots * cache_len / page_size)")
    ap.add_argument("--kernel", action="store_true",
                    help="decode attention through the fused Pallas "
                         "paged-decode kernel (paged mode only; interpret "
                         "mode on CPU, Mosaic with REPRO_PALLAS_COMPILE=1)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (with --temperature > 0)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="sample only among the k highest-logit tokens "
                         "(0 = no top-k cut; with --temperature > 0)")
    ap.add_argument("--rep-penalty", type=float, default=1.0,
                    help="CTRL-style repetition penalty on already-emitted "
                         "tokens (1.0 = off; applies to greedy slots too)")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip ahead-of-traffic compilation of the two "
                         "engine shapes")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ServingEngine(params, cfg, slots=args.slots,
                           cache_len=args.cache_len, chunk=args.chunk,
                           paged=args.paged, page_size=args.page_size,
                           num_blocks=args.num_blocks or None,
                           use_kernel=args.kernel, seed=args.seed)
    if not args.no_warmup:
        t0 = time.time()
        engine.warmup()
        print(f"warmup: compiled prefill ({args.slots},{engine.chunk}) + "
              f"decode ({args.slots},1) in {time.time() - t0:.2f}s")
    key = jax.random.PRNGKey(args.seed + 1)
    for i in range(args.requests):
        key, sub = jax.random.split(key)
        prompt = jax.random.randint(sub, (4 + i % 4,), 0,
                                    cfg.vocab_size).tolist()
        engine.submit(Request(i, prompt, max_new=args.max_new,
                              temperature=args.temperature,
                              top_p=args.top_p, top_k=args.top_k,
                              rep_penalty=args.rep_penalty))
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    st = engine.stats
    mode = (f"paged:{engine.num_blocks}x{engine.page_size}"
            + ("+kernel" if engine.use_kernel else "")
            if engine.paged else "dense")
    print(f"{cfg.name}: served {len(done)} requests, {toks} tokens in "
          f"{dt:.2f}s ({toks/dt:.1f} tok/s, slots={args.slots}, {mode})")
    print(f"  engine calls: {st['prefill_calls']} prefill (chunk="
          f"{engine.chunk}) + {st['decode_calls']} decode ticks, "
          f"{st['admitted']} admissions, {st['backpressure']} backpressure")
    for r in sorted(done, key=lambda r: r.req_id)[:4]:
        print(f"  req{r.req_id}: prompt={r.prompt} -> {r.generated}")


if __name__ == "__main__":
    main()
