"""Serving launcher: chunked-prefill continuous-batching engine over a
smoke config (CPU) — the production-mesh serve path is proven by dryrun.py.

The engine runs exactly two steady-state jitted shapes: the chunked-
prefill step ``(slots, chunk)`` and the decode tick ``(slots, 1)``;
``--warmup`` compiles both ahead of traffic and reports the compile time
separately from serving throughput.

``--paged`` (default on) stores KV through a block table: per-request
cache memory is ceil((prompt + max_new) / page_size) pages from a shared
``--num-blocks`` pool instead of one worst-case ``cache_len`` per slot,
and the queue backpressures when the pool is exhausted.  ``--no-paged``
selects the dense per-slot ring caches (bitwise reference semantics).
Paged mode shares identical prompt-prefix pages content-addressed
(stored once, refcounted, copy-on-write on divergence — skipped pages
skip their prefill compute too); ``--no-prefix-share`` disables it and
``--prefix-tokens N`` prepends a common system prompt so the fast path
has traffic to hit.
``--temperature``/``--top-p``/``--top-k``/``--rep-penalty`` sample
in-jit with per-slot PRNG streams (temperature 0 = greedy,
bitwise-stable; the repetition penalty reads an in-jit per-slot
seen-token mask).  ``--kernel`` decodes through the fused Pallas
paged-attention kernel (block-table-driven page DMA) instead of the
chunked-gather scan path.

``--replicas N`` serves the same workload through a **fleet**: N engine
replicas on heterogeneous simulated devices (``--devices``, cycled from
``perfmodel.DEVICE_CATALOG``) behind one FIFO queue, placed by Eq. 2
estimated completion time (fast devices take proportionally more
requests), with ``--standby`` spare replicas registered in the broker's
backup pool and ``--heartbeat-every`` ticks between failure-detection
rounds (``--reliability`` < 1 makes seeded mid-decode failures happen:
in-flight requests re-prefill on the drafted replacement).
``--chaos-rate`` > 0 additionally injects a seeded ``FaultPlan`` (crash,
straggle, partition, pool_pressure, corrupt) over the first
``--chaos-ticks`` ticks; requests carry a ``--max-retries`` budget and
the run reports structured per-request outcomes instead of raising away
partial results (``--strict`` restores the raise).

Stateful failover (fleet mode): ``--migration auto|always|never``
controls whether soft-drain and rebalance victims move by verified
KV-page migration (checksum-chained export/import, dedup against the
destination's content registry) instead of re-prefilling — ``auto``
decides per request with the bytes-over-bandwidth vs recompute cost
model; ``--snapshot-every N`` records decode snapshots so crash victims
resume from their last snapshot; ``--rebalance-every N`` adds the load
trigger; ``--hold-pages N`` keeps refcount-zero registered pages LRU-
held so imports and re-admissions dedup against them.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b \
        --requests 8 --max-new 16 --slots 4 --chunk 16 --page-size 16
    PYTHONPATH=src python -m repro.launch.serve --replicas 3 \
        --devices rtx4090,rtx3080 --standby 1 --requests 12
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.core.perfmodel import DEVICE_CATALOG
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServingEngine
from repro.serve.router import FleetRouter, sim_node


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ALL_ARCHS), default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--chunk", type=int, default=16,
                    help="prefill chunk: admission costs ceil(S/chunk) "
                         "jitted steps instead of S")
    ap.add_argument("--paged", dest="paged", action="store_true",
                    default=True,
                    help="block-table KV cache: per-request pages from a "
                         "shared pool (default)")
    ap.add_argument("--no-paged", dest="paged", action="store_false",
                    help="dense per-slot ring caches (reference semantics)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="positions per cache page (paged mode)")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="pool size in pages; 0 = same memory as the dense "
                         "cache (slots * cache_len / page_size)")
    ap.add_argument("--no-prefix-share", dest="prefix_share",
                    action="store_false", default=True,
                    help="disable content-addressed prefix sharing "
                         "(paged mode: identical prompt-prefix pages are "
                         "stored once, attached by refcount, and "
                         "copy-on-write on divergence)")
    ap.add_argument("--prefix-tokens", type=int, default=0,
                    help="prepend this many shared system-prompt tokens "
                         "to every request (exercises prefix sharing)")
    ap.add_argument("--kernel", action="store_true",
                    help="decode attention through the fused Pallas "
                         "paged-decode kernel (paged mode only; interpret "
                         "mode on CPU, Mosaic with REPRO_PALLAS_COMPILE=1)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (with --temperature > 0)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="sample only among the k highest-logit tokens "
                         "(0 = no top-k cut; with --temperature > 0)")
    ap.add_argument("--rep-penalty", type=float, default=1.0,
                    help="CTRL-style repetition penalty on already-emitted "
                         "tokens (1.0 = off; applies to greedy slots too)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through a FleetRouter with this many "
                         "engine replicas (1 = single engine, no router)")
    ap.add_argument("--devices", default="rtx4090,rtx3080",
                    help="comma-separated DEVICE_CATALOG names cycled "
                         "across replicas (fleet mode placement speeds)")
    ap.add_argument("--standby", type=int, default=0,
                    help="spare replicas registered in the broker backup "
                         "pool, drafted by speed match on failure")
    ap.add_argument("--heartbeat-every", type=int, default=0,
                    help="fleet mode: broker heartbeat round every N "
                         "engine ticks (0 = no failure detection)")
    ap.add_argument("--reliability", type=float, default=1.0,
                    help="per-heartbeat replica survival probability "
                         "(< 1 exercises seeded mid-decode failover)")
    ap.add_argument("--chaos-rate", type=float, default=0.0,
                    help="fleet mode: per-(tick, replica) probability of "
                         "a seeded injected fault (crash / straggle / "
                         "partition / pool_pressure); 0 = no fault plan")
    ap.add_argument("--chaos-ticks", type=int, default=64,
                    help="inject faults over the first N ticks of the "
                         "chaos plan (with --chaos-rate > 0)")
    ap.add_argument("--chaos-seed", type=int, default=-1,
                    help="fault-plan RNG seed (-1 = reuse --seed)")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="per-request retry budget: a request requeued "
                         "by failures more than this many times ends "
                         "with outcome failed_retries instead of "
                         "retrying forever")
    ap.add_argument("--strict", action="store_true",
                    help="fleet mode: raise on any failed request "
                         "instead of returning partial results with "
                         "structured outcomes")
    ap.add_argument("--migration", choices=["auto", "always", "never"],
                    default="auto",
                    help="fleet mode: soft-drain/rebalance victims move "
                         "via verified KV-page migration instead of "
                         "re-prefilling; 'auto' runs the bytes-over-"
                         "bandwidth vs recompute cost model, 'always' "
                         "skips it, 'never' restores drain-and-requeue")
    ap.add_argument("--snapshot-every", type=int, default=8,
                    help="fleet mode: record (prefix digests, generated "
                         "tokens) for every admitted request every N "
                         "ticks so crash victims resume decoding instead "
                         "of starting over (0 = off)")
    ap.add_argument("--rebalance-every", type=int, default=0,
                    help="fleet mode: every N ticks, migrate the newest "
                         "request off a replica whose pending tokens "
                         "exceed rebalance_factor x the least-loaded "
                         "peer (0 = off)")
    ap.add_argument("--hold-pages", type=int, default=0,
                    help="per-engine LRU hold: keep up to N refcount-"
                         "zero registered pages resident instead of "
                         "scrubbing, so re-admissions and migration "
                         "imports dedup against them (paged sharing "
                         "mode only)")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip ahead-of-traffic compilation of the two "
                         "engine shapes")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)

    def build_engine():
        return ServingEngine(params, cfg, slots=args.slots,
                             cache_len=args.cache_len, chunk=args.chunk,
                             paged=args.paged, page_size=args.page_size,
                             num_blocks=args.num_blocks or None,
                             use_kernel=args.kernel, seed=args.seed,
                             share_prefix=args.prefix_share,
                             hold_pages=args.hold_pages)

    if args.replicas > 1:
        serve_fleet(args, cfg, build_engine)
        return

    engine = build_engine()
    if not args.no_warmup:
        t0 = time.time()
        engine.warmup()
        print(f"warmup: compiled prefill ({args.slots},{engine.chunk}) + "
              f"decode ({args.slots},1) in {time.time() - t0:.2f}s")
    key = jax.random.PRNGKey(args.seed + 1)
    system = _system_prefix(args, cfg)
    for i in range(args.requests):
        key, sub = jax.random.split(key)
        prompt = system + jax.random.randint(sub, (4 + i % 4,), 0,
                                             cfg.vocab_size).tolist()
        engine.submit(Request(i, prompt, max_new=args.max_new,
                              temperature=args.temperature,
                              top_p=args.top_p, top_k=args.top_k,
                              rep_penalty=args.rep_penalty))
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    st = engine.stats
    mode = (f"paged:{engine.num_blocks}x{engine.page_size}"
            + ("+kernel" if engine.use_kernel else "")
            if engine.paged else "dense")
    print(f"{cfg.name}: served {len(done)} requests, {toks} tokens in "
          f"{dt:.2f}s ({toks/dt:.1f} tok/s, slots={args.slots}, {mode})")
    print(f"  engine calls: {st['prefill_calls']} prefill (chunk="
          f"{engine.chunk}) + {st['decode_calls']} decode ticks, "
          f"{st['admitted']} admissions, {st['backpressure']} backpressure")
    if engine._can_share:
        print(f"  prefix sharing: {st['shared_pages']} pages attached "
              f"({st['shared_tokens']} prompt tokens skipped prefill), "
              f"{st['cow_copies']} copy-on-write")
    for r in sorted(done, key=lambda r: r.req_id)[:4]:
        print(f"  req{r.req_id}: prompt={r.prompt} -> {r.generated}")


def _system_prefix(args, cfg):
    """--prefix-tokens: a deterministic shared system prompt prepended to
    every request so the prefix-sharing fast path has something to hit."""
    if not args.prefix_tokens:
        return []
    key = jax.random.PRNGKey(args.seed + 7)
    return jax.random.randint(key, (args.prefix_tokens,), 0,
                              cfg.vocab_size).tolist()


def serve_fleet(args, cfg, build_engine):
    """--replicas > 1: broker-routed fleet over heterogeneous simulated
    devices, one shared FIFO queue, ECT placement, seeded failover."""
    devices = [d.strip() for d in args.devices.split(",") if d.strip()]
    for d in devices:
        if d not in DEVICE_CATALOG:
            raise SystemExit(f"--devices: unknown device {d!r} "
                             f"(catalog: {', '.join(DEVICE_CATALOG)})")
    def node(i):
        return sim_node(devices[i % len(devices)],
                        reliability=args.reliability)
    plan = None
    if args.chaos_rate > 0:
        from repro.serve.faults import FaultPlan
        chaos_seed = args.seed if args.chaos_seed < 0 else args.chaos_seed
        plan = FaultPlan.seeded(
            chaos_seed, ticks=args.chaos_ticks,
            replica_ids=list(range(args.replicas + args.standby)),
            rate=args.chaos_rate)
        print(f"chaos: {len(plan)} seeded faults over {args.chaos_ticks} "
              f"ticks (seed={chaos_seed}, rate={args.chaos_rate})")
    router = FleetRouter(
        [(build_engine(), node(i)) for i in range(args.replicas)],
        [(build_engine(), node(args.replicas + i))
         for i in range(args.standby)],
        seed=args.seed, fault_plan=plan, migration=args.migration,
        snapshot_every=args.snapshot_every,
        rebalance_every=args.rebalance_every)
    if not args.no_warmup:
        t0 = time.time()
        for rep in router.replicas:
            rep.engine.warmup()
        print(f"warmup: compiled {len(router.replicas)} replicas in "
              f"{time.time() - t0:.2f}s (standby replicas compile when "
              f"drafted)")
    key = jax.random.PRNGKey(args.seed + 1)
    system = _system_prefix(args, cfg)
    for i in range(args.requests):
        key, sub = jax.random.split(key)
        prompt = system + jax.random.randint(sub, (4 + i % 4,), 0,
                                             cfg.vocab_size).tolist()
        router.submit(Request(i, prompt, max_new=args.max_new,
                              temperature=args.temperature,
                              top_p=args.top_p, top_k=args.top_k,
                              rep_penalty=args.rep_penalty,
                              max_retries=args.max_retries))
    t0 = time.time()
    res = router.run(heartbeat_every=args.heartbeat_every,
                     strict=args.strict)
    dt = time.time() - t0
    done = res.completed
    toks = sum(len(r.generated) for r in done)
    st = router.stats
    print(f"{cfg.name} fleet: {len(router.live_replicas())} live replicas "
          f"served {len(done)}/{len(done) + len(res.failed)} requests, "
          f"{toks} tokens in {dt:.2f}s ({toks / dt:.1f} tok/s, "
          f"{res.ticks} ticks)")
    print(f"  outcomes: " + ", ".join(
        f"{k}={v}" for k, v in sorted(res.outcomes().items())))
    print(f"  router: {st['placed']} placements, {st['held']} held ticks, "
          f"{st['failures']} failures, {st['requeued']} requeued, "
          f"{st['replacements']} drafted from backup")
    degraded = {k: st[k] for k in ("soft_drains", "preempted", "straggles",
                                   "partitions", "partition_heals",
                                   "partition_escalations", "pool_pressure",
                                   "injected_crashes", "corrupt_faults")
                if st.get(k)}
    if degraded:
        print("  degraded mode: " + ", ".join(
            f"{k}={v}" for k, v in sorted(degraded.items())))
    failover = {k: st[k] for k in ("migrations", "migration_fallbacks",
                                   "rebalances", "rebalance_holds",
                                   "snapshot_restores") if st.get(k)}
    reps = list(router.replicas) + list(router._standby.values())
    deduped = sum(r.engine.stats.get("deduped_pages", 0) for r in reps)
    resumed = sum(r.engine.stats.get("resumed_tokens", 0) for r in reps)
    rejects = sum(r.engine.stats.get("import_rejects", 0) for r in reps)
    if failover or deduped or resumed or rejects:
        print("  stateful failover: " + ", ".join(
            f"{k}={v}" for k, v in sorted(failover.items()))
            + (f", deduped_pages={deduped}" if deduped else "")
            + (f", resumed_tokens={resumed}" if resumed else "")
            + (f", import_rejects={rejects}" if rejects else ""))
    for r in sorted(res.failed, key=lambda r: r.req_id)[:6]:
        tr = res.traces.get(r.req_id, {})
        print(f"  FAILED req{r.req_id}: outcome={r.outcome} "
              f"retries={r.retries}/{r.max_retries} "
              f"placements={tr.get('placements')}")
    shared = sum(r.engine.stats.get("shared_pages", 0)
                 for r in router.replicas)
    cow = sum(r.engine.stats.get("cow_copies", 0) for r in router.replicas)
    if any(r.engine._can_share for r in router.replicas):
        print(f"  prefix sharing: {shared} pages attached fleet-wide, "
              f"{cow} copy-on-write")
    for rep in sorted(router.replicas, key=lambda r: r.replica_id):
        state = "live" if rep.alive else "DEAD"
        print(f"  replica {rep.replica_id} [{rep.node.device.name}, "
              f"{state}]: served {len(rep.served)} requests "
              f"{sorted(rep.served)}")


if __name__ == "__main__":
    main()
