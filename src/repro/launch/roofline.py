"""Roofline analysis from compiled dry-run artifacts.

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective
bytes are parsed out of the (post-SPMD, per-device) HLO text by summing
operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops, scaled back to global by ×chips.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one HLO op line: "%name = TYPE[SHAPE]{layout} opcode(..."  (also tuples)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[-a-z]*\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_ENTRY_RE = re.compile(r"^ENTRY [^(]*\(([^)]*)\)\s*->\s*(\([^)]*\)|[^ {]+)",
                       re.MULTILINE)


def entry_io_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device argument/output bytes from the post-SPMD ENTRY signature
    (memory_analysis aggregates host-wide, so compute the honest per-chip
    numbers here)."""
    m = _ENTRY_RE.search(hlo_text)
    if not m:
        return {"args": 0.0, "outputs": 0.0}
    return {"args": _shape_bytes(m.group(1)),
            "outputs": _shape_bytes(m.group(2))}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective-kind byte totals (per-device program), static count
    (every op once, regardless of loop trip counts)."""
    out = {k: 0.0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        out[m.group(2)] += _shape_bytes(m.group(1))
    out["total"] = sum(out.values())
    return out


# ---------------------------------------------------------------------------
# Loop-aware collective accounting.
#
# XLA's module-level cost/byte counters count a while-loop body ONCE, but a
# scanned 126-layer stack executes its body 126 times.  We rebuild the
# computation call graph from the HLO text, parse each while loop's trip
# count from its condition (compare against a constant), and multiply
# collective bytes by the product of enclosing trip counts.
# ---------------------------------------------------------------------------

_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)[^\n]*\{", re.MULTILINE)
_CALL_RE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_WHILE_RE = re.compile(r"while\([^)]*\),\s*condition=%?([\w\.\-]+),\s*"
                       r"body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """computation name -> body text (brace-balanced top-level blocks)."""
    comps: Dict[str, str] = {}
    for m in _COMP_RE.finditer(hlo_text):
        start = m.end()
        depth = 1
        i = start
        while depth and i < len(hlo_text):
            c = hlo_text[i]
            if c == "{":
                depth += 1
            elif c == "}":
                depth -= 1
            i += 1
        comps[m.group(1)] = hlo_text[m.start():i]
    return comps


def loop_aware_collectives(hlo_text: str) -> Dict[str, float]:
    """Collective bytes with while-loop trip-count multipliers applied.
    Returns per-kind totals (per-device)."""
    comps = _split_computations(hlo_text)
    entry = None
    for name in comps:
        if "ENTRY" in comps[name][:80] or hlo_text.find(f"ENTRY %{name}") >= 0:
            entry = name
    if entry is None:                       # fall back: last computation
        entry = list(comps)[-1] if comps else None
    if entry is None:
        return collective_bytes(hlo_text)

    trip_counts: Dict[str, float] = {}       # body comp -> trips
    for m in _WHILE_RE.finditer(hlo_text):
        cond, body = m.group(1), m.group(2)
        consts = [int(c) for c in _CONST_RE.findall(comps.get(cond, ""))]
        trip_counts[body] = float(max(consts)) if consts else 1.0

    totals = {k: 0.0 for k in _COLLECTIVES}
    seen_stack = []

    def visit(name: str, mult: float):
        if name not in comps or name in seen_stack or len(seen_stack) > 64:
            return
        seen_stack.append(name)
        body = comps[name]
        for m in _OP_RE.finditer(body):
            totals[m.group(2)] += _shape_bytes(m.group(1)) * mult
        callees = [m.group(1) for m in _CALL_RE.finditer(body)]
        for bm in _BRANCH_RE.finditer(body):
            callees += re.split(r",\s*%?", bm.group(1))
        for callee in callees:
            callee = callee.strip().lstrip("%")
            if callee and callee != name:
                visit(callee, mult * trip_counts.get(callee, 1.0))
        seen_stack.pop()

    visit(entry, 1.0)
    totals["total"] = sum(totals.values())
    return totals


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    exec_flops: float            # global, analytic (incl. remat)
    hbm_bytes: float             # global, analytic traffic model
    coll_bytes: float            # global, loop-aware from compiled HLO
    model_flops: float           # useful compute (no remat/overcompute)
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    useful_ratio: float          # model_flops / exec_flops
    per_chip_peak_mem: float = 0.0
    coll_detail: Optional[dict] = None
    raw_cost_flops: float = 0.0  # XLA static counter (loop bodies once)
    raw_cost_bytes: float = 0.0
    raw_coll_bytes_static: float = 0.0

    def to_dict(self):
        return asdict(self)


def analyze(*, arch: str, shape: str, mesh_name: str, chips: int,
            cost: dict, hlo_text: str, exec_flops: float, hbm_bytes: float,
            model_flops: float, per_chip_peak_mem: float = 0.0) -> Roofline:
    """exec_flops / hbm_bytes: analytic global workload (the PALEO-style
    §3.7 model — XLA's module counters count while bodies once, so the
    compiled artifact supplies structure + collectives, the workload model
    supplies magnitudes).  Collectives: loop-aware parse of the compiled
    per-device HLO, scaled ×chips to global."""
    coll = loop_aware_collectives(hlo_text)
    coll_static = collective_bytes(hlo_text)
    coll_total = coll["total"] * chips
    compute_s = exec_flops / (chips * PEAK_FLOPS)
    memory_s = hbm_bytes / (chips * HBM_BW)
    collective_s = coll["total"] / ICI_BW          # per-chip bytes / link bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        exec_flops=exec_flops, hbm_bytes=hbm_bytes, coll_bytes=coll_total,
        model_flops=model_flops,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=max(terms, key=terms.get),
        useful_ratio=model_flops / exec_flops if exec_flops else 0.0,
        per_chip_peak_mem=per_chip_peak_mem,
        coll_detail={k: v * chips for k, v in coll.items()},
        raw_cost_flops=float(cost.get("flops", 0.0)),
        raw_cost_bytes=float(cost.get("bytes accessed", 0.0)),
        raw_coll_bytes_static=coll_static["total"],
    )
