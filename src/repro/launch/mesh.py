"""Production meshes.

Single pod: (data=16, model=16) — 256 TPU v5e chips.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the ``pod`` axis is
the slow-link (DCI) analogue of the paper's Internet links — batch/FSDP
traffic stays inside a pod, only gradient all-reduce crosses pods.

Functions, not module-level constants: importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_stages: int = 0):
    """Small mesh over however many (host) devices exist — used by the
    pipelined-executor example, not by the dry-run."""
    n = len(jax.devices())
    stages = n_stages or n
    return jax.make_mesh((stages,), ("stage",))


def data_axes(mesh) -> tuple:
    """Axes used for batch/data parallelism ('pod' joins 'data' if present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis(mesh) -> str:
    return "model"
