import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape × mesh):
    jit(step, in_shardings, out_shardings).lower(**ShapeDtypeStructs)
    .compile()  -> memory_analysis() + cost_analysis() + roofline terms.

No arrays are allocated; XLA compiles the full SPMD program for the
production mesh (16×16 single pod / 2×16×16 multi-pod) on 512 host
placeholder devices.  Any sharding mismatch, compile-time OOM or
unsupported collective is a bug in the system and fails here.

Usage:
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES, baseline_pairs,
                           get_config, shape_applicable)
from repro.core.workload import (analytic_hbm_bytes, model_flops,
                                 model_flops_6nd, step_flops)
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import (batch_specs, cache_specs,
                                    default_hint_rule, opt_specs,
                                    param_specs, to_shardings)
from repro.launch.specs import input_specs
from repro.models.hints import wrap_with_hints
from repro.optim.adamw import adamw
from repro.serve.engine import (make_decode_step, make_engine_step,
                                make_prefill_step)
from repro.train.step import make_train_step

from jax.sharding import NamedSharding, PartitionSpec as P


def build_jitted(arch: str, shape_name: str, mesh, *, microbatches: int = 1,
                 kv_chunk: int = 1024, paged: bool = False,
                 page_size: int = 16, use_kernel: bool = False):
    """Returns (jitted_fn, ordered_args_sds).  ``paged=True`` lowers the
    continuous-batching ENGINE step for decode shapes — paged block-pool
    caches, block table and per-slot sampling operands included — instead
    of the plain dense decode step.  ``use_kernel=True`` (implies paged)
    lowers the fused Pallas paged-decode attention inside that step."""
    paged = (paged or use_kernel) and INPUT_SHAPES[shape_name].kind == "decode"
    use_kernel = use_kernel and paged
    spec = input_specs(arch, shape_name, paged=paged, page_size=page_size)
    cfg, shape = spec["cfg"], spec["shape"]
    p_specs = param_specs(spec["params"], mesh)
    p_sh = to_shardings(p_specs, mesh, spec["params"])

    decode_tp = (shape.kind == "decode"
                 and os.environ.get("REPRO_DECODE_TP", "1") == "1")
    hint_rule = default_hint_rule(mesh, batch_size=shape.global_batch,
                                  decode_tp=decode_tp)
    from repro.launch.mesh import data_axes
    n_data = 1
    for a in data_axes(mesh):
        n_data *= mesh.shape[a]
    moe_groups = n_data if shape.global_batch % n_data == 0 else 1
    if shape.kind == "train":
        optimizer = adamw(1e-4,
                          state_bits=int(os.environ.get("REPRO_OPT_BITS",
                                                        "32")))
        remat_policy = os.environ.get("REPRO_REMAT_POLICY", "full")
        step = wrap_with_hints(
            make_train_step(cfg, optimizer, microbatches=microbatches,
                            remat=True, remat_policy=remat_policy),
            mesh, hint_rule,
            moe_groups=moe_groups,
            moe_ep=os.environ.get("REPRO_MOE_EP", "1") == "1")
        o_sh = to_shardings(opt_specs(spec["opt_state"], p_specs, mesh),
                            mesh, spec["opt_state"])
        b_sh = to_shardings(batch_specs(spec["batch"], mesh), mesh,
                            spec["batch"])
        jitted = jax.jit(step,
                         in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None))
        args = (spec["params"], spec["opt_state"], spec["batch"])
    else:
        B = shape.global_batch
        c_sh = to_shardings(cache_specs(spec["caches"], mesh, batch_size=B),
                            mesh, spec["caches"])
        # decode: replicate token activations across data (weight-stationary
        # 2D TP via the "residual" hint); caches stay batch-sharded
        shard_b = B > 1 and not decode_tp
        b_sh = to_shardings(batch_specs(spec["batch"], mesh,
                                        shard_batch=shard_b), mesh,
                            spec["batch"])
        pos_sh = to_shardings(batch_specs({"p": spec["positions"]}, mesh,
                                          shard_batch=shard_b), mesh)["p"]
        if paged:
            # the serving-engine step itself: paged pools + block table +
            # in-jit per-slot sampling.  Tokens arrive as a raw (B, 1)
            # array (the engine step has no batch dict).
            fn = make_engine_step(cfg, kv_chunk=kv_chunk, paged=True,
                                  use_kernel=use_kernel)
        else:
            fn = (make_prefill_step(cfg, kv_chunk=kv_chunk)
                  if shape.kind == "prefill"
                  else make_decode_step(cfg, kv_chunk=kv_chunk))
        fn = wrap_with_hints(fn, mesh, hint_rule,
                             moe_groups=1 if decode_tp else moe_groups,
                             moe_ep=(not decode_tp and os.environ.get(
                                 "REPRO_MOE_EP", "1") == "1"))
        if paged:
            toks = spec["batch"]["tokens"]
            tok_sh = to_shardings(batch_specs({"t": toks}, mesh,
                                              shard_batch=shard_b), mesh)["t"]
            tab_sh = to_shardings(batch_specs({"t": spec["table"]}, mesh,
                                              shard_batch=shard_b), mesh)["t"]
            sm = spec["sampling"]
            seen_sh = to_shardings(batch_specs({"t": sm["seen"]}, mesh,
                                               shard_batch=shard_b),
                                   mesh)["t"]
            rep = NamedSharding(mesh, P())
            jitted = jax.jit(fn,
                             in_shardings=(p_sh, c_sh, seen_sh, tok_sh,
                                           pos_sh, tab_sh, rep, rep, rep,
                                           rep, rep),
                             out_shardings=(None, c_sh, seen_sh))
            args = (spec["params"], spec["caches"], sm["seen"], toks,
                    spec["positions"], spec["table"], sm["rng_keys"],
                    sm["temperature"], sm["top_p"], sm["top_k"],
                    sm["rep_penalty"])
        else:
            jitted = jax.jit(fn,
                             in_shardings=(p_sh, c_sh, b_sh, pos_sh),
                             out_shardings=(None, c_sh))
            args = (spec["params"], spec["caches"], spec["batch"],
                    spec["positions"])
    return jitted, args, cfg, shape


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            out_dir: Optional[str] = None, verbose: bool = True,
            microbatches: int = 1, kv_chunk: int = 1024,
            paged: bool = False, page_size: int = 16,
            use_kernel: bool = False) -> Dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh.devices.size
    t0 = time.time()
    # build_jitted downgrades paged for non-decode shapes; record what is
    # actually lowered, not what was requested
    paged = (paged or use_kernel) and INPUT_SHAPES[shape_name].kind == "decode"
    use_kernel = use_kernel and paged
    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "chips": chips, "status": "ok", "paged": bool(paged),
                 "kernel": bool(use_kernel)}
    try:
        jitted, args, cfg, shape = build_jitted(
            arch, shape_name, mesh, microbatches=microbatches,
            kv_chunk=kv_chunk, paged=paged, page_size=page_size,
            use_kernel=use_kernel)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):   # some jax versions wrap it
            cost = cost[0] if cost else {}
        print_mem = {
            k: getattr(mem, k, None) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes")}
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_name}] "
                  f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
            print("  memory_analysis:", print_mem)
            print("  cost_analysis: flops=%.3e bytes=%.3e" % (
                cost.get("flops", 0.0), cost.get("bytes accessed", 0.0)))

        # MODEL_FLOPS (useful compute) for the roofline ratio
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            kw = dict(batch=B, seq=1, kind="decode", kv_cache_len=S)
        else:
            kw = dict(batch=B, seq=S, kind=shape.kind)
        mflops = model_flops(cfg, **kw)
        eflops = step_flops(
            cfg, batch=kw["batch"], seq=kw["seq"], kind=shape.kind,
            kv_cache_len=kw.get("kv_cache_len", 0),
            remat_policy=os.environ.get("REPRO_REMAT_POLICY", "full"))
        hbm = analytic_hbm_bytes(cfg, **kw)

        hlo = compiled.as_text()
        io = rl.entry_io_bytes(hlo)
        per_chip_peak = io["args"]
        roof = rl.analyze(arch=arch, shape=shape_name, mesh_name=mesh_name,
                          chips=chips, cost=cost, hlo_text=hlo,
                          exec_flops=eflops, hbm_bytes=hbm,
                          model_flops=mflops,
                          per_chip_peak_mem=per_chip_peak)
        rec.update({
            "lower_s": t_lower, "compile_s": t_compile,
            "per_chip_arg_bytes": io["args"],
            "per_chip_out_bytes": io["outputs"],
            "memory_analysis": {k: (int(v) if v is not None else None)
                                for k, v in print_mem.items()},
            "cost_flops_per_device": cost.get("flops", 0.0),
            "cost_bytes_per_device": cost.get("bytes accessed", 0.0),
            "model_flops_analytic": mflops,
            "model_flops_6nd": model_flops_6nd(
                get_config(arch),
                tokens=B * (S if shape.kind != "decode" else 1)),
            "roofline": roof.to_dict(),
        })
        if verbose:
            print(f"  per-chip args {io['args']/1e9:.2f}GB "
                  f"out {io['outputs']/1e9:.2f}GB")
            print(f"  roofline: compute {roof.compute_s*1e3:.2f}ms | "
                  f"memory {roof.memory_s*1e3:.2f}ms | "
                  f"collective {roof.collective_s*1e3:.2f}ms "
                  f"-> bottleneck={roof.bottleneck} "
                  f"useful={roof.useful_ratio:.2f}")
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_name}] FAILED: "
                  f"{rec['error']}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=float)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ASSIGNED_ARCHS) + ["bert-large",
                                                              "gpt3-24l"])
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable (arch × shape) pair")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--paged", action="store_true",
                    help="decode shapes: lower the paged (block-table) "
                         "serving-engine step instead of the dense decode")
    ap.add_argument("--kernel", action="store_true",
                    help="decode shapes: lower the paged engine step with "
                         "the fused Pallas paged-decode attention kernel "
                         "(implies --paged)")
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()

    if args.all:
        pairs, skips = baseline_pairs()
        for arch, shape in pairs:
            run_one(arch, shape, multi_pod=args.multi_pod, out_dir=args.out,
                    microbatches=args.microbatches, paged=args.paged,
                    page_size=args.page_size, use_kernel=args.kernel)
        for arch, shape, why in skips:
            print(f"[skip] {arch} × {shape}: {why}")
        return
    assert args.arch and args.shape, "--arch/--shape or --all"
    cfg = get_config(args.arch)
    ok, why = shape_applicable(cfg, INPUT_SHAPES[args.shape])
    if not ok:
        print(f"[skip] {args.arch} × {args.shape}: {why}")
        return
    run_one(args.arch, args.shape, multi_pod=args.multi_pod,
            out_dir=args.out, microbatches=args.microbatches,
            paged=args.paged, page_size=args.page_size,
            use_kernel=args.kernel)


if __name__ == "__main__":
    main()
