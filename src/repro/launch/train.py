"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --steps 100 \
        [--smoke] [--batch 8] [--seq 128] [--ckpt-dir /tmp/ckpt]

``--smoke`` uses the reduced same-family config (CPU-runnable); the full
configs are meant for the production mesh (see dryrun.py for the
lower/compile proof on 256/512 chips).
"""
from __future__ import annotations

import argparse

from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.data.synthetic import SyntheticConfig, SyntheticLM
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ALL_ARCHS), default="gpt3-24l")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--noise", type=float, default=0.05)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    loader = SyntheticLM(SyntheticConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, noise=args.noise,
        ext_embed_dim=cfg.ext_embed_dim, seed=args.seed))
    tcfg = TrainConfig(steps=args.steps, lr=args.lr,
                       microbatches=args.microbatches,
                       ckpt_dir=args.ckpt_dir or "/tmp/repro_ckpt",
                       ckpt_every=args.ckpt_every, seed=args.seed)
    trainer = Trainer(cfg, tcfg, loader)
    if args.ckpt_every:
        trainer.maybe_restore()
    print(f"training {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size} (optimal loss ≈ "
          f"{loader.optimal_loss():.3f})")
    trainer.fit()


if __name__ == "__main__":
    main()
