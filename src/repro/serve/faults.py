"""Deterministic fault-injection plane for the serving fleet.

The paper's failure model — and the broker's seeded heartbeat — is
binary: a node is either healthy or dead, and recovery is a full
re-prefill on a survivor.  Real decentralized fleets mostly fail
*partially*: stragglers (thermal throttling, a contended uplink),
transient network partitions, memory pressure from a co-tenant.  This
module gives the ``FleetRouter`` a reproducible source of exactly those
faults, so every degraded-mode behavior can be asserted in tests and
benches instead of sampled from ``CompNode.reliability``.

A ``FaultPlan`` is a schedule of typed ``Fault`` records keyed by fleet
tick.  The router consumes ``plan.at(tick)`` at the START of each tick
and applies each fault to the (live) target replica:

``crash``
    The existing death path: broker quit, drain, requeue-from-prompt,
    speed-matched standby draft.  KV state is LOST.

``straggle(factor, duration)``
    The replica's engine ticks cost ``factor``x fleet clock for
    ``duration`` fleet ticks: it executes one engine tick then sits busy
    for the remainder, and its tick-latency EWMA (which scales its ECT)
    rises toward ``factor``.  Past the router's ``drain_factor`` the
    replica is soft-drained.  KV state is KEPT (victims of a soft drain
    re-prefill elsewhere, but the replica itself never loses state).

``partition(duration)``
    The replica is unreachable for ``duration`` ticks: no dispatch, no
    engine ticks, no harvest — but engine state is RETAINED.  On heal,
    in-flight work resumes mid-decode without re-prefill.  A partition
    outlasting the router's ``partition_timeout`` escalates to ``crash``
    (the fleet cannot tell a long partition from a death).

``pool_pressure(pages, duration)``
    ``pages`` paged-pool pages are withheld from NEW admissions for
    ``duration`` ticks (a co-tenant grabbed memory).  Reservation-backed
    decode of already-admitted requests is untouched — pressure can only
    backpressure the queue, never crash an in-flight request.  Engines
    holding refcount-zero registered pages (the LRU prefix hold) give
    those up first.

``corrupt(duration)``
    Every migration payload EXPORTED from the replica during the episode
    arrives with flipped bytes (a flaky uplink / bad DIMM on the wire
    path).  The importer's checksum-chain verification must reject the
    transfer — wrong content is never served — and the router falls back
    to requeue-from-prompt, exactly as if migration had never been
    attempted.  A replica that exports nothing is unaffected.

Plans are either hand-built (``FaultPlan([...])`` / ``plan.add``) for
targeted tests or drawn from a seeded RNG (``FaultPlan.seeded``) for
property tests and the chaos bench.  Equal seeds produce equal plans.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, Iterator, List, Sequence, Tuple

import numpy as np

FAULT_KINDS = ("crash", "straggle", "partition", "pool_pressure", "corrupt")


@dataclass(frozen=True)
class Fault:
    """One typed fault striking ``replica_id`` at fleet tick ``tick``.

    ``factor`` is the straggle tick-cost multiplier; ``duration`` the
    episode length in fleet ticks (straggle / partition / pool_pressure
    / corrupt); ``pages`` the pool pages withheld (pool_pressure).
    Fields irrelevant to a kind are ignored."""
    tick: int
    replica_id: int
    kind: str
    factor: float = 4.0
    duration: int = 4
    pages: int = 2

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"Fault: unknown kind {self.kind!r} "
                             f"(expected one of {FAULT_KINDS})")
        if self.tick < 0:
            raise ValueError(f"Fault: tick must be >= 0, got {self.tick}")
        if self.kind == "straggle" and self.factor < 1.0:
            raise ValueError(f"Fault: straggle factor must be >= 1.0, "
                             f"got {self.factor}")
        if self.kind != "crash" and self.duration < 1:
            raise ValueError(f"Fault: duration must be >= 1 tick, "
                             f"got {self.duration}")
        if self.kind == "pool_pressure" and self.pages < 1:
            raise ValueError(f"Fault: pool_pressure must withhold >= 1 "
                             f"page, got {self.pages}")


class FaultPlan:
    """An immutable-once-running schedule of faults, keyed by fleet tick.

    ``at(tick)`` returns the faults striking at that tick (insertion
    order — deterministic).  Multiple faults may share a tick, including
    several on one replica."""

    def __init__(self, faults: Iterable[Fault] = ()):
        self._by_tick: Dict[int, List[Fault]] = {}
        self._n = 0
        for f in faults:
            self.add(f)

    def add(self, fault: Fault) -> "FaultPlan":
        if not isinstance(fault, Fault):
            raise TypeError(f"FaultPlan.add: expected a Fault, "
                            f"got {type(fault).__name__}")
        self._by_tick.setdefault(fault.tick, []).append(fault)
        self._n += 1
        return self

    def at(self, tick: int) -> List[Fault]:
        # a COPY: handing out the internal per-tick list would let a
        # caller mutate the immutable-once-running schedule in place
        return list(self._by_tick.get(tick, ()))

    def __len__(self) -> int:
        return self._n

    def __iter__(self) -> Iterator[Fault]:
        for t in sorted(self._by_tick):
            yield from self._by_tick[t]

    def __repr__(self) -> str:
        kinds: Dict[str, int] = {}
        for f in self:
            kinds[f.kind] = kinds.get(f.kind, 0) + 1
        return f"FaultPlan({self._n} faults: {kinds})"

    @classmethod
    def seeded(cls, seed: int, *, ticks: int,
               replica_ids: Sequence[int],
               rate: float = 0.08,
               kinds: Tuple[str, ...] = FAULT_KINDS,
               max_factor: float = 4.0,
               max_duration: int = 6,
               max_pages: int = 4) -> "FaultPlan":
        """Draw a random plan: each (tick, replica) pair independently
        suffers a fault with probability ``rate``; kind uniform over
        ``kinds``, straggle factor uniform in [2, max_factor], durations
        and withheld pages uniform integers.  Same seed, same plan."""
        for k in kinds:
            if k not in FAULT_KINDS:
                raise ValueError(f"FaultPlan.seeded: unknown kind {k!r}")
        rng = np.random.RandomState(seed)
        plan = cls()
        for t in range(ticks):
            for rid in replica_ids:
                if rng.random_sample() >= rate:
                    continue
                kind = kinds[rng.randint(len(kinds))]
                plan.add(Fault(
                    tick=t, replica_id=rid, kind=kind,
                    factor=float(2.0 + rng.random_sample()
                                 * max(0.0, max_factor - 2.0)),
                    duration=int(rng.randint(1, max_duration + 1)),
                    pages=int(rng.randint(1, max_pages + 1))))
        return plan
