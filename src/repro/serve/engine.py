"""Serving engine: prefill + single-token decode over the model zoo's
cache pytrees (KV / MLA-latent / SSM-state / SWA-ring), greedy or
temperature sampling, and a slot-based continuous batcher.

``make_prefill_step`` / ``make_decode_step`` are the functions the
multi-pod dry-run lowers for the ``prefill_32k`` / ``decode_32k`` /
``long_500k`` input shapes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import forward, init_cache

Array = jax.Array


def make_prefill_step(cfg: ModelConfig, *, kv_chunk: int = 1024) -> Callable:
    """(params, caches, batch, positions) -> (last-token logits, caches).
    batch carries (B, S_prompt) tokens (and/or stub embeddings)."""
    def prefill_step(params, caches, batch, positions):
        logits, _, caches = forward(params, cfg, batch, caches=caches,
                                    positions=positions, kv_chunk=kv_chunk)
        return logits[:, -1:, :], caches
    return prefill_step


def make_decode_step(cfg: ModelConfig, *, kv_chunk: int = 1024,
                     masked_slots: bool = False) -> Callable:
    """(params, caches, tokens (B,1) | embeds, positions (B,1)) ->
    (logits (B,1,V), caches).  One new token against the running cache.
    ``masked_slots=True`` makes rows with position -1 cache/state no-ops
    (continuous-batching idle slots)."""
    def decode_step(params, caches, batch, positions):
        logits, _, caches = forward(params, cfg, batch, caches=caches,
                                    positions=positions, decode=True,
                                    kv_chunk=kv_chunk,
                                    masked_slots=masked_slots)
        return logits, caches
    return decode_step


def sample(logits: Array, key, temperature: float = 0.0) -> Array:
    """logits (B,1,V) -> tokens (B,1)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1) \
        .astype(jnp.int32)


def generate(params, cfg: ModelConfig, prompts: Array, *, max_new: int,
             cache_len: Optional[int] = None, temperature: float = 0.0,
             seed: int = 0, jit: bool = True) -> Array:
    """Batched generation.  prompts: (B, S_prompt) int32.
    Returns (B, S_prompt + max_new)."""
    B, S0 = prompts.shape
    cache_len = cache_len or (S0 + max_new)
    caches = init_cache(cfg, B, cache_len)
    prefill = make_prefill_step(cfg)
    decode = make_decode_step(cfg)
    if jit:
        prefill, decode = jax.jit(prefill), jax.jit(decode)
    pos = jnp.broadcast_to(jnp.arange(S0, dtype=jnp.int32)[None], (B, S0))
    logits, caches = prefill(params, caches, {"tokens": prompts}, pos)
    key = jax.random.PRNGKey(seed)
    out = [prompts]
    tok = sample(logits, key, temperature)
    for t in range(max_new):
        out.append(tok)
        if t == max_new - 1:
            break
        key, sub = jax.random.split(key)
        posd = jnp.full((B, 1), S0 + t, jnp.int32)
        logits, caches = decode(params, caches, {"tokens": tok}, posd)
        tok = sample(logits, sub, temperature)
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Slot-based continuous batcher (production-style serving loop)
# ---------------------------------------------------------------------------

@dataclass
class Request:
    req_id: int
    prompt: List[int]
    max_new: int
    generated: List[int] = field(default_factory=list)
    pending: int = -1            # next token to feed/emit
    done: bool = False


class ServingEngine:
    """Fixed-slot continuous batching: requests occupy slots; every engine
    tick decodes one token for all active slots; finished slots are
    refilled from the queue.  Per-slot positions keep the shared batched
    cache consistent; idle slots step with position -1, which every cache
    kind treats as a masked no-op for attention purposes."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 cache_len: int = 512):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.cache_len = cache_len
        self.caches = init_cache(cfg, slots, cache_len)
        self._decode = jax.jit(make_decode_step(cfg, masked_slots=True))
        self.active: List[Optional[Request]] = [None] * slots
        self.positions = [0] * slots
        self.queue: List[Request] = []
        self.finished: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _step(self, toks, pos):
        return self._decode(self.params, self.caches,
                            {"tokens": toks}, pos)

    def _reset_slot(self, s: int) -> None:
        """Clear one slot's cache/state before reuse — stale KV entries
        (valid positions from the previous occupant) and carried SSM
        states would otherwise leak into the next request."""
        def clear(path, leaf):
            name = str(getattr(path[-1], "key", path[-1]))
            bdim = 1 if "stack" in str(path[0:1]) or leaf.ndim == 0 else 0
            # stack-period caches carry a leading period axis
            bdim = 1 if leaf.ndim >= 2 and leaf.shape[0] != self.slots else 0
            idx = (slice(None),) * bdim + (s,)
            fill = -1 if name == "pos" else 0
            return leaf.at[idx].set(jnp.asarray(fill, leaf.dtype))
        self.caches = jax.tree_util.tree_map_with_path(clear, self.caches)

    def _admit(self) -> None:
        """Token-level admission: walk the prompt through the slot's cache
        one token per step (other slots masked with position -1)."""
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.pop(0)
                self.active[s] = req
                self._reset_slot(s)
                logits = None
                for t, tok in enumerate(req.prompt):
                    toks = jnp.zeros((self.slots, 1), jnp.int32).at[s, 0].set(tok)
                    pos = jnp.full((self.slots, 1), -1, jnp.int32).at[s, 0].set(t)
                    logits, self.caches = self._step(toks, pos)
                self.positions[s] = len(req.prompt)
                req.pending = int(jnp.argmax(logits[s, -1]))

    def tick(self) -> int:
        """One engine iteration: feed each active slot's pending token,
        emit it, and compute the next.  Returns #active slots."""
        self._admit()
        act = [s for s in range(self.slots) if self.active[s] is not None]
        if not act:
            return 0
        toks = jnp.zeros((self.slots, 1), jnp.int32)
        pos = jnp.full((self.slots, 1), -1, jnp.int32)
        for s in act:
            toks = toks.at[s, 0].set(self.active[s].pending)
            pos = pos.at[s, 0].set(self.positions[s])
        logits, self.caches = self._step(toks, pos)
        for s in act:
            req = self.active[s]
            req.generated.append(req.pending)
            req.pending = int(jnp.argmax(logits[s, -1]))
            self.positions[s] += 1
            if len(req.generated) >= req.max_new:
                req.done = True
                self.finished.append(req)
                self.active[s] = None
        return len(act)

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        for _ in range(max_ticks):
            if not self.tick() and not self.queue:
                break
        return self.finished
