"""Serving engine: prefill + single-token decode over the model zoo's
cache pytrees (KV / MLA-latent / SSM-state / SWA-ring), greedy or
temperature sampling, and a slot-based continuous batcher with
**chunked prefill** (admission costs ceil(S/chunk) jitted steps, the
decode tick is one jitted step over all slots).

``make_prefill_step`` / ``make_decode_step`` are the functions the
multi-pod dry-run lowers for the ``prefill_32k`` / ``decode_32k`` /
``long_500k`` input shapes; ``make_engine_step`` is the single
masked-slot step function behind ``ServingEngine`` (chunked prefill and
decode tick are the same callable at two shapes).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ATTN, ModelConfig
from repro.models.transformer import forward, init_cache, unembed

Array = jax.Array


def make_prefill_step(cfg: ModelConfig, *, kv_chunk: int = 1024) -> Callable:
    """(params, caches, batch, positions) -> (last-token logits, caches).
    batch carries (B, S_prompt) tokens (and/or stub embeddings)."""
    def prefill_step(params, caches, batch, positions):
        logits, _, caches = forward(params, cfg, batch, caches=caches,
                                    positions=positions, kv_chunk=kv_chunk)
        return logits[:, -1:, :], caches
    return prefill_step


def make_decode_step(cfg: ModelConfig, *, kv_chunk: int = 1024) -> Callable:
    """(params, caches, tokens (B,1) | embeds, positions (B,1)) ->
    (logits (B,1,V), caches).  One new token against the running cache.
    (Continuous batching goes through ``make_engine_step`` instead, whose
    masked-slot semantics are the tested path.)"""
    def decode_step(params, caches, batch, positions):
        logits, _, caches = forward(params, cfg, batch, caches=caches,
                                    positions=positions, decode=True,
                                    kv_chunk=kv_chunk)
        return logits, caches
    return decode_step


def make_engine_step(cfg: ModelConfig, *, kv_chunk: int = 1024) -> Callable:
    """(params, caches, tokens (B,S), positions (B,S)) ->
    (greedy next-token ids (B,1) int32, caches).

    The one step function behind the continuous batcher: the SAME jitted
    callable serves chunked prefill (S = chunk) and the batched decode
    tick (S = 1, which statically selects the single-token cache paths —
    absorbed MLA etc.).  Rows/entries with position -1 are cache/state
    no-ops, so idle slots ride along for free.  Only the LAST position is
    unembedded (the engine never consumes mid-chunk logits) and greedy
    argmax happens inside the jit, so one (slots, vocab) matmul and
    (B, 1) token ids are all that leave the step, never (B, S, V) logits.
    """
    def engine_step(params, caches, tokens, positions):
        h, _, caches = forward(params, cfg, {"tokens": tokens},
                               caches=caches, positions=positions,
                               decode=tokens.shape[1] == 1,
                               kv_chunk=kv_chunk, compute_logits=False,
                               masked_slots=True)
        logits = unembed(params, cfg, h[:, -1:, :])
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches
    return engine_step


def sample(logits: Array, key, temperature: float = 0.0) -> Array:
    """logits (B,1,V) -> tokens (B,1)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1) \
        .astype(jnp.int32)


def generate(params, cfg: ModelConfig, prompts: Array, *, max_new: int,
             cache_len: Optional[int] = None, temperature: float = 0.0,
             seed: int = 0, jit: bool = True) -> Array:
    """Batched generation.  prompts: (B, S_prompt) int32.
    Returns (B, S_prompt + max_new)."""
    B, S0 = prompts.shape
    cache_len = cache_len or (S0 + max_new)
    caches = init_cache(cfg, B, cache_len)
    prefill = make_prefill_step(cfg)
    decode = make_decode_step(cfg)
    if jit:
        prefill, decode = jax.jit(prefill), jax.jit(decode)
    pos = jnp.broadcast_to(jnp.arange(S0, dtype=jnp.int32)[None], (B, S0))
    logits, caches = prefill(params, caches, {"tokens": prompts}, pos)
    key = jax.random.PRNGKey(seed)
    out = [prompts]
    tok = sample(logits, key, temperature)
    for t in range(max_new):
        out.append(tok)
        if t == max_new - 1:
            break
        key, sub = jax.random.split(key)
        posd = jnp.full((B, 1), S0 + t, jnp.int32)
        logits, caches = decode(params, caches, {"tokens": tok}, posd)
        tok = sample(logits, sub, temperature)
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Slot-based continuous batcher (production-style serving loop)
# ---------------------------------------------------------------------------

@dataclass
class Request:
    req_id: int
    prompt: List[int]
    max_new: int
    generated: List[int] = field(default_factory=list)
    pending: int = -1            # next token to feed/emit
    done: bool = False


def _clear_slot(caches, s):
    """Zero one slot's cache/state across every cache kind (KV /
    MLA-latent / SSM-state / SWA-ring) and invalidate its positions.

    Slot is ALWAYS the first axis after the structural prefix: prefix
    caches are (slots, ...); stack caches carry one leading ``n_periods``
    axis, i.e. (periods, slots, ...).  Deciding on the pytree path (not
    on shape coincidences like ``shape[0] != slots``) keeps the reset
    correct when n_periods happens to equal the slot count."""
    def clear(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        top = str(getattr(path[0], "key", path[0]))
        bdim = 1 if top == "stack" else 0
        if leaf.ndim <= bdim:            # defensive: scalar/period-only leaf
            return leaf
        idx = (slice(None),) * bdim + (s,)
        fill = -1 if name == "pos" else 0
        return leaf.at[idx].set(jnp.asarray(fill, leaf.dtype))
    return jax.tree_util.tree_map_with_path(clear, caches)


class ServingEngine:
    """Fixed-slot continuous batching with **chunked prefill**.

    Requests occupy slots; admission runs the new request's prompt through
    the shared slot cache in ``ceil(S_prompt / chunk)`` batched forward
    steps (other slots masked with position -1) instead of S single-token
    decode calls; every engine tick then decodes one token for all active
    slots in a single jitted step over the stacked slot state.  Finished
    slots are recycled through a cache-clearing reset so no KV entries or
    recurrent state leak into the next occupant.

    Per-slot positions keep the shared batched cache consistent; idle
    slots step with position -1, which every cache kind treats as a
    write/state no-op.  Cache buffers are donated to the jitted step on
    accelerator backends so the slot cache is updated in place.

    ``stats`` counts jitted forward calls (``prefill_calls`` /
    ``decode_calls``) — the admission cost of an S-token prompt is
    ``ceil(S/chunk)`` calls, which tests and benchmarks rely on.
    """

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 cache_len: int = 512, chunk: int = 32):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.cache_len = cache_len
        self.chunk = max(1, min(chunk, cache_len))
        # full (non-windowed) attention layers must never wrap the ring:
        # every position of prompt + generation needs a live cache entry.
        # SWA rings may wrap freely — chunked prefill attends over
        # [pre-write ring ∥ chunk], so eviction never loses in-window keys.
        specs = tuple(cfg.prefix_layers) + tuple(cfg.period)
        self._bounded_ctx = any(s.mixer == ATTN for s in specs)
        self.caches = init_cache(cfg, slots, cache_len)
        # buffer donation is a no-op on CPU and would only warn
        donate = jax.default_backend() != "cpu"
        self._step_fn = jax.jit(make_engine_step(cfg),
                                donate_argnums=(1,) if donate else ())
        self._reset_fn = jax.jit(_clear_slot,
                                 donate_argnums=(0,) if donate else ())
        self.active: List[Optional[Request]] = [None] * slots
        self.positions = [0] * slots
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.stats = {"prefill_calls": 0, "decode_calls": 0, "admitted": 0}

    def submit(self, req: Request) -> None:
        if not req.prompt:
            raise ValueError(
                f"ServingEngine: request {req.req_id} has an empty prompt — "
                f"at least one prompt token is required to seed decoding")
        if self._bounded_ctx and len(req.prompt) + req.max_new > self.cache_len:
            # only full-attention caches bound the context: SWA rings wrap
            # exactly under the window mask, recurrent state has no length
            raise ValueError(
                f"ServingEngine: request {req.req_id} needs "
                f"{len(req.prompt)} prompt + {req.max_new} new tokens but "
                f"cache_len={self.cache_len}; full-attention caches must "
                f"not wrap (raise cache_len or lower max_new)")
        self.queue.append(req)

    def warmup(self) -> None:
        """Compile the two engine shapes ahead of serving: the chunked-
        prefill step (slots, chunk) and the decode tick (slots, 1).  Runs
        them with every position masked (-1), which is a cache no-op, so
        warmup never perturbs engine state."""
        for C in sorted({self.chunk, 1}):
            toks = jnp.zeros((self.slots, C), jnp.int32)
            pos = jnp.full((self.slots, C), -1, jnp.int32)
            _, self.caches = self._step_fn(self.params, self.caches,
                                           toks, pos)
        # compile the reset against a FREE slot only (resetting it is
        # harmless — admission resets again); never touch a live one
        free = [s for s in range(self.slots) if self.active[s] is None]
        if free:
            self.caches = self._reset_fn(self.caches, free[-1])
        jax.block_until_ready(self.caches)

    def _admit(self) -> None:
        """Chunked-prefill admission: reset the slot's cache, then walk the
        prompt through it ``chunk`` tokens per jitted step (other slots
        masked with position -1).  The final chunk may be shorter — it
        compiles once per distinct remainder length."""
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.pop(0)
                self.active[s] = req
                self.caches = self._reset_fn(self.caches, s)
                prompt = jnp.asarray(req.prompt, jnp.int32)
                S = int(prompt.shape[0])
                nxt = None
                for c0 in range(0, S, self.chunk):
                    piece = prompt[c0:c0 + self.chunk]
                    C = int(piece.shape[0])
                    toks = jnp.zeros((self.slots, C), jnp.int32).at[s].set(piece)
                    pos = jnp.full((self.slots, C), -1, jnp.int32).at[s].set(
                        jnp.arange(c0, c0 + C, dtype=jnp.int32))
                    nxt, self.caches = self._step_fn(self.params, self.caches,
                                                     toks, pos)
                    self.stats["prefill_calls"] += 1
                self.positions[s] = S
                req.pending = int(nxt[s, -1])
                self.stats["admitted"] += 1

    def tick(self) -> int:
        """One engine iteration: feed each active slot's pending token,
        emit it, and compute the next — a single jitted decode step over
        all slots.  Returns #active slots."""
        self._admit()
        act = [s for s in range(self.slots) if self.active[s] is not None]
        if not act:
            return 0
        toks = jnp.zeros((self.slots, 1), jnp.int32)
        pos = jnp.full((self.slots, 1), -1, jnp.int32)
        for s in act:
            toks = toks.at[s, 0].set(self.active[s].pending)
            pos = pos.at[s, 0].set(self.positions[s])
        nxt, self.caches = self._step_fn(self.params, self.caches, toks, pos)
        self.stats["decode_calls"] += 1
        for s in act:
            req = self.active[s]
            req.generated.append(req.pending)
            req.pending = int(nxt[s, 0])
            self.positions[s] += 1
            if len(req.generated) >= req.max_new:
                req.done = True
                self.finished.append(req)
                self.active[s] = None
        return len(act)

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        for _ in range(max_ticks):
            if not self.tick() and not self.queue:
                break
        return self.finished
