"""Serving engine: prefill + single-token decode over the model zoo's
cache pytrees (KV / MLA-latent / SSM-state / SWA-ring), greedy or
per-slot temperature/top-p sampling, and a slot-based continuous batcher
with **chunked prefill** (admission costs ceil(S/chunk) jitted steps, the
decode tick is one jitted step over all slots) and a **paged slot cache**
(vLLM-style block table: per-request cache memory is ceil((prompt +
max_new) / page_size) pages from a shared pool instead of one
engine-wide worst-case ``cache_len`` per slot).

``make_prefill_step`` / ``make_decode_step`` are the functions the
multi-pod dry-run lowers for the ``prefill_32k`` / ``decode_32k`` /
``long_500k`` input shapes; ``make_engine_step`` is the single
masked-slot step function behind ``ServingEngine`` (chunked prefill and
decode tick are the same callable at two shapes).
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ATTN, MAMBA, RWKV, SWA, ModelConfig
from repro.models.layers import NEG_INF, swa_ring_blocks
from repro.models.transformer import forward, init_cache, unembed

Array = jax.Array

# pool leaves of paged attention-family caches (block-indexed, shared
# across slots); everything else in a cache pytree is per-slot state
POOL_LEAVES = ("k", "v", "pos", "ckv", "krope")


def make_prefill_step(cfg: ModelConfig, *, kv_chunk: int = 1024) -> Callable:
    """(params, caches, batch, positions) -> (last-token logits, caches).
    batch carries (B, S_prompt) tokens (and/or stub embeddings)."""
    def prefill_step(params, caches, batch, positions):
        logits, _, caches = forward(params, cfg, batch, caches=caches,
                                    positions=positions, kv_chunk=kv_chunk)
        return logits[:, -1:, :], caches
    return prefill_step


def make_decode_step(cfg: ModelConfig, *, kv_chunk: int = 1024) -> Callable:
    """(params, caches, tokens (B,1) | embeds, positions (B,1)) ->
    (logits (B,1,V), caches).  One new token against the running cache.
    (Continuous batching goes through ``make_engine_step`` instead, whose
    masked-slot semantics are the tested path.)"""
    def decode_step(params, caches, batch, positions):
        logits, _, caches = forward(params, cfg, batch, caches=caches,
                                    positions=positions, decode=True,
                                    kv_chunk=kv_chunk)
        return logits, caches
    return decode_step


def topp_sample(keys: Array, logits: Array, temperature: Array,
                top_p: Array, top_k: Optional[Array] = None) -> Array:
    """Per-row temperature + nucleus (+ optional top-k) sampling, fully
    in-jit.

    keys: (B, 2) uint32 raw threefry key data; logits: (B, V) float32;
    temperature / top_p: (B,) float32; top_k: (B,) int32, 0 = no top-k
    limit.  Rows are sampled independently (vmapped categorical) from
    the smallest prefix of the sorted distribution whose mass reaches
    top_p, intersected with the top_k highest-logit tokens (the top
    token always stays, so top_p -> 0 or top_k == 1 degenerates to
    greedy).  Returns (B, 1) int32.
    """
    V = logits.shape[-1]
    lg = logits / jnp.maximum(temperature, 1e-6)[:, None]
    order = jnp.argsort(-lg, axis=-1)
    slg = jnp.take_along_axis(lg, order, axis=-1)
    probs = jax.nn.softmax(slg, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = (cum - probs) < top_p[:, None]          # top-1 always kept
    if top_k is not None:
        k_eff = jnp.where(top_k > 0, top_k, V)     # 0 disables the cut
        keep &= jnp.arange(V, dtype=jnp.int32)[None, :] < k_eff[:, None]
    slg = jnp.where(keep, slg, NEG_INF)
    idx = jax.vmap(jax.random.categorical)(keys, slg)            # (B,)
    return jnp.take_along_axis(order, idx[:, None], axis=-1).astype(jnp.int32)


def apply_repetition_penalty(logits: Array, seen: Array,
                             rep_penalty: Array) -> Array:
    """CTRL-style repetition penalty, per row: logits of tokens the row
    has already seen (prompt + generated so far) are divided by the
    penalty when positive and multiplied when negative, discouraging
    re-emission.  logits: (B, V) f32; seen: (B, V) bool;
    rep_penalty: (B,) f32, 1.0 = off.  Rows with penalty 1.0 are
    returned bitwise-untouched (the ``where`` keeps the original
    values), so default slots never drift."""
    pen = rep_penalty[:, None]
    scaled = jnp.where(logits > 0, logits / pen, logits * pen)
    return jnp.where(seen & (pen != 1.0), scaled, logits)


def make_engine_step(cfg: ModelConfig, *, kv_chunk: int = 1024,
                     paged: bool = False,
                     use_kernel: bool = False) -> Callable:
    """(params, caches, seen (B,V) bool, tokens (B,S), positions (B,S),
    table (B,n_cols), rng_keys (B,2) uint32, temperature (B,), top_p
    (B,), top_k (B,) int32, rep_penalty (B,)) ->
    (next-token ids (B,1) int32, caches, seen).

    The one step function behind the continuous batcher: the SAME jitted
    callable serves chunked prefill (S = chunk) and the batched decode
    tick (S = 1, which statically selects the single-token cache paths —
    absorbed MLA etc.).  Rows/entries with position -1 are cache/state
    no-ops, so idle slots ride along for free.  Only the LAST position is
    unembedded (the engine never consumes mid-chunk logits) and token
    selection happens inside the jit — greedy argmax for slots with
    temperature 0 (bitwise-identical to the greedy-only engine),
    per-slot temperature/top-p/top-k via a (B, 2) PRNG-key array
    otherwise — so one (slots, vocab) matmul and (B, 1) token ids are
    all that leave the step, never (B, S, V) logits.

    ``seen`` is the per-slot already-emitted-token mask, maintained
    in-jit: the step scatters its valid input tokens (prompt chunks and
    fed-back decode tokens alike) before selection, so repetition
    penalty (``rep_penalty`` != 1, CTRL-style) sees prompt + generation
    so far without any (B, V) traffic leaving the device.  The scatter,
    the penalty and the sampling branch are all ``lax.cond``-gated on
    the same predicates, so the all-default steady state pays for none
    of them.  (Gating the scatter is sound: a slot's mask is only ever
    read while its penalty != 1, a request's penalty is fixed for its
    lifetime — so every step of a penalized request runs with the cond
    on — and the mask is cleared host-side at admission.)

    ``paged=True`` routes every attention-family cache access through the
    block ``table`` (dense engines pass a dummy, which the forward
    ignores); ``use_kernel=True`` additionally dispatches paged S=1
    decode attention to the fused Pallas paged-decode kernel (the block
    table drives the page DMA — no gathered K/V copy in HBM).
    """
    def engine_step(params, caches, seen, tokens, positions, table,
                    rng_keys, temperature, top_p, top_k, rep_penalty):
        B = tokens.shape[0]
        rows = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32)[:, None],
                                tokens.shape)
        seen = jax.lax.cond(
            jnp.any(rep_penalty != 1.0),
            lambda sn: sn.at[rows, tokens].max(positions >= 0),
            lambda sn: sn, seen)
        h, _, caches = forward(params, cfg, {"tokens": tokens},
                               caches=caches, positions=positions,
                               decode=tokens.shape[1] == 1,
                               kv_chunk=kv_chunk, compute_logits=False,
                               masked_slots=True,
                               block_table=table if paged else None,
                               use_kernel=use_kernel)
        logits = unembed(params, cfg, h[:, -1:, :])              # (B,1,V)
        lg = logits[:, 0, :]
        # both conds keep the all-default steady state on the cheap path:
        # no (B, V) where-rewrite, no vocab sort/softmax at runtime
        lg = jax.lax.cond(
            jnp.any(rep_penalty != 1.0),
            lambda l: apply_repetition_penalty(l, seen, rep_penalty),
            lambda l: l, lg)
        greedy = jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
        tok = jax.lax.cond(
            jnp.any(temperature > 0.0),
            lambda: jnp.where(temperature[:, None] > 0.0,
                              topp_sample(rng_keys, lg, temperature,
                                          top_p, top_k), greedy),
            lambda: greedy)
        return tok, caches, seen
    return engine_step


def sample(logits: Array, key, temperature: float = 0.0) -> Array:
    """logits (B,1,V) -> tokens (B,1)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1) \
        .astype(jnp.int32)


def generate(params, cfg: ModelConfig, prompts: Array, *, max_new: int,
             cache_len: Optional[int] = None, temperature: float = 0.0,
             seed: int = 0, jit: bool = True) -> Array:
    """Batched generation.  prompts: (B, S_prompt) int32.
    Returns (B, S_prompt + max_new)."""
    B, S0 = prompts.shape
    cache_len = cache_len or (S0 + max_new)
    caches = init_cache(cfg, B, cache_len)
    prefill = make_prefill_step(cfg)
    decode = make_decode_step(cfg)
    if jit:
        prefill, decode = jax.jit(prefill), jax.jit(decode)
    pos = jnp.broadcast_to(jnp.arange(S0, dtype=jnp.int32)[None], (B, S0))
    logits, caches = prefill(params, caches, {"tokens": prompts}, pos)
    key = jax.random.PRNGKey(seed)
    out = [prompts]
    tok = sample(logits, key, temperature)
    for t in range(max_new):
        out.append(tok)
        if t == max_new - 1:
            break
        key, sub = jax.random.split(key)
        # np, not jnp: a device op here would dispatch once per decoded
        # token (HOT001); decode() converts the operand batch once
        posd = np.full((B, 1), S0 + t, np.int32)
        logits, caches = decode(params, caches, {"tokens": tok}, posd)
        tok = sample(logits, sub, temperature)
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Slot-based continuous batcher (production-style serving loop)
# ---------------------------------------------------------------------------

@dataclass
class Request:
    req_id: int
    prompt: List[int]
    max_new: int
    temperature: float = 0.0     # 0 -> greedy (bitwise-stable default)
    top_p: float = 1.0
    top_k: int = 0               # 0 -> no top-k cut
    rep_penalty: float = 1.0     # 1.0 -> no repetition penalty
    generated: List[int] = field(default_factory=list)
    pending: int = -1            # next token to feed/emit
    done: bool = False
    # chain digests of the prompt's full prefix pages, stamped by
    # drain_requests() so a failover requeue keeps its prefix identity
    # for the router's affinity tie-break (None until drained)
    prefix_digests: Optional[List[int]] = None
    # degraded-mode lifecycle (managed by serve.router.FleetRouter):
    # retries counts requeue-from-prompt events caused by faults (crash,
    # soft-drain, partition timeout — preemption is free); past
    # max_retries the request stops consuming the fleet and fails
    # terminally.  outcome is stamped exactly once when the request
    # leaves the system: "ok" | "failed_retries" | "failed_unservable"
    # | "deadline_exceeded" (None while still in flight).
    retries: int = 0
    max_retries: int = 3
    outcome: Optional[str] = None
    # snapshot restore (stateful failover): tokens this request had
    # already decoded at the router's last periodic snapshot.  Stamped
    # by the crash path before requeue; admission re-prefills prompt +
    # resume as ONE extended prompt (chunked prefill is bitwise-equal to
    # the decode that first produced that KV) and restores ``generated``
    # from it, so only tokens decoded since the snapshot are re-decoded.
    resume_tokens: Optional[List[int]] = None


class BlockAllocator:
    """Host-side free-list over the paged cache pool, **reference-counted
    and content-addressed**.

    Admission is **reservation-based**: a request reserves its worst case
    (``ceil((prompt + max_new) / page_size)`` pages) up front, takes pages
    lazily (prompt pages at admit, one page per crossed boundary during
    decode), and releases everything on finish.  Because reserved pages
    are guaranteed allocatable, decode-time extends can never fail —
    pool exhaustion surfaces only as admission backpressure (the queue
    waits) instead of a mid-decode crash.

    **Refcounts**: ``alloc_one`` hands out a page at refcount 1;
    ``share`` bumps it (a second slot's table row now points at the same
    physical page); ``free`` decrements and only returns a page to the
    free list — and reports it in its return value, so the engine scrubs
    it — when the count reaches zero.  Freeing an unheld page asserts
    (double-free protection).

    **Content addressing**: ``register`` maps a prefix-page digest to a
    resident block; ``lookup`` resolves a digest back to the block.  A
    ``check`` value (parent block id + the page's exact tokens) rides
    along with every registration: lookup verifies it, so a digest
    collision falls back to a miss (the caller allocates a private page)
    instead of silently attaching wrong content.  Because the check
    chains through parent *block ids*, matching check values imply
    byte-identical token prefixes by induction.  Registrations hold no
    refcount of their own and are dropped when the block is physically
    freed.

    **LRU hold** (``hold_limit`` > 0): up to that many refcount-zero
    registered pages are HELD instead of freed — registration and
    content intact — so a popular prefix readmitted after a brief idle
    gap attaches its pages instead of re-prefilling.  Held pages count
    as available capacity (``can_reserve``); a reservation that needs
    them evicts the oldest first, and evicted pages land on
    ``take_scrub()`` so the engine zeroes their stale content before
    reuse.  ``hold_limit == 0`` (the default) keeps the exact
    free-at-refcount-zero semantics."""

    def __init__(self, num_blocks: int, hold_limit: int = 0):
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, -1, -1))
        self.reserved = 0
        # pages withheld from NEW reservations by fault injection
        # (pool_pressure): existing reservations are untouched, so
        # decode-time extends stay infallible — pressure only
        # backpressures admission.  May transiently exceed n_free.
        self.withheld = 0
        self.hold_limit = hold_limit
        self._held: List[int] = []                 # LRU, oldest first
        # held pages evicted back to the free list: content is stale,
        # the engine drains this and scrubs them before any reuse
        self._pending_scrub: List[int] = []
        self.refcount: Dict[int, int] = {}
        self._by_digest: Dict[int, int] = {}       # digest -> block
        self._entries: Dict[int, tuple] = {}       # block -> (digest, check)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_held(self) -> int:
        return len(self._held)

    def can_reserve(self, n: int) -> bool:
        return self.n_free + self.n_held - self.reserved - self.withheld >= n

    def reserve(self, n: int) -> bool:
        """Set aside ``n`` future pages; False = backpressure."""
        if not self.can_reserve(n):
            return False
        # reservations must be backed by truly-free pages (alloc_one
        # pops the free list): evict exactly the held pages this one
        # needs, oldest first
        short = n - (self.n_free - self.reserved - self.withheld)
        if short > 0:
            self.evict_held(short)
        self.reserved += n
        return True

    def evict_held(self, k: int) -> List[int]:
        """Evict up to ``k`` oldest held pages back to the free list
        (deregistered; queued on ``take_scrub`` — their content is stale
        from the pool's point of view)."""
        out: List[int] = []
        for _ in range(max(0, min(k, len(self._held)))):
            b = self._held.pop(0)
            self.deregister(b)
            self._free.append(b)
            self._pending_scrub.append(b)
            out.append(b)
        return out

    def take_scrub(self) -> List[int]:
        """Blocks evicted from the hold since the last call — free-listed
        but carrying stale content; the caller owns scrubbing them."""
        out, self._pending_scrub = self._pending_scrub, []
        return out

    def alloc_one(self) -> int:
        """Take one page against an existing reservation (refcount 1)."""
        assert self._free, "BlockAllocator: reservation invariant violated"
        self.reserved -= 1
        assert self.reserved >= 0, "alloc_one without a reservation"
        b = self._free.pop()
        self.refcount[b] = 1
        return b

    def share(self, block: int) -> None:
        """Another table row now references ``block``.  Reviving a HELD
        page (refcount zero, kept resident by the LRU hold) takes it
        back out of the hold at refcount 1 — the hold paying off."""
        if not self.refcount.get(block, 0) and block in self._held:
            self._held.remove(block)
            self.refcount[block] = 1
            return
        assert self.refcount.get(block, 0) > 0, \
            f"BlockAllocator: share of unheld block {block}"
        self.refcount[block] += 1

    def lookup(self, digest: int, check: tuple) -> Optional[int]:
        """Resolve a prefix-page digest to its resident block, or None on
        a miss or a verified hash collision (check mismatch)."""
        b = self._by_digest.get(digest)
        if b is None or self._entries[b][1] != check:
            return None
        return b

    def register(self, digest: int, check: tuple, block: int) -> bool:
        """Advertise ``block`` as holding the prefix page ``digest``.
        First registration wins (an existing entry — including a
        colliding one — is kept); a block advertises one digest."""
        if digest in self._by_digest or block in self._entries:
            return False
        self._by_digest[digest] = block
        self._entries[block] = (digest, check)
        return True

    def deregister(self, block: int) -> None:
        """Drop the block's digest advertisement (content is about to
        diverge, or the block is being physically freed)."""
        ent = self._entries.pop(block, None)
        if ent is not None:
            self._by_digest.pop(ent[0], None)

    def is_registered(self, block: int) -> bool:
        return block in self._entries

    def free(self, blocks: List[int], unreserve: int = 0) -> List[int]:
        """Drop one reference per listed block.  Returns the blocks whose
        refcount reached zero — ONLY those went back to the free list and
        only those may (and must) be scrubbed; pages still shared by
        another slot stay live and untouched."""
        freed: List[int] = []
        for b in blocks:
            rc = self.refcount.get(b, 0)
            assert rc > 0, f"BlockAllocator: double free of [{b}]"
            if rc == 1:
                del self.refcount[b]
                if self.hold_limit > 0 and self.is_registered(b):
                    # LRU hold: keep the page resident — registration
                    # and content intact — so a readmitted prefix can
                    # attach it; NOT reported freed (must not be
                    # scrubbed while held)
                    self._held.append(b)
                    self.evict_held(len(self._held) - self.hold_limit)
                else:
                    self.deregister(b)
                    self._free.append(b)
                    freed.append(b)
            else:
                self.refcount[b] = rc - 1
        self.reserved -= unreserve
        assert self.reserved >= 0 and self.n_free <= self.num_blocks
        return freed


def _clear_slot(caches, s, skip_pools: bool = False):
    """Zero one slot's cache/state across every cache kind (KV /
    MLA-latent / SSM-state / SWA-ring) and invalidate its positions.

    Slot is ALWAYS the first axis after the structural prefix: prefix
    caches are (slots, ...); stack caches carry one leading ``n_periods``
    axis, i.e. (periods, slots, ...).  Deciding on the pytree path (not
    on shape coincidences like ``shape[0] != slots``) keeps the reset
    correct when n_periods happens to equal the slot count.

    ``skip_pools=True`` (paged engines) leaves block-pool leaves alone —
    pools are indexed by block id, not slot, and recycled blocks are
    scrubbed by ``_clear_blocks`` when they return to the free list."""
    def clear(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        if skip_pools and name in POOL_LEAVES:
            return leaf
        top = str(getattr(path[0], "key", path[0]))
        bdim = 1 if top == "stack" else 0
        if leaf.ndim <= bdim:            # defensive: scalar/period-only leaf
            return leaf
        idx = (slice(None),) * bdim + (s,)
        fill = -1 if name == "pos" else 0
        return leaf.at[idx].set(jnp.asarray(fill, leaf.dtype))
    return jax.tree_util.tree_map_with_path(clear, caches)


def _pool_mixer(cfg: ModelConfig, path) -> str:
    """Mixer kind ("attn" / "swa" / ...) of the layer owning a pool leaf,
    from the leaf's pytree path.  Per-cache-kind pools give SWA layers
    their own (smaller) block-id space, so scrubs must route each block
    vector to the right pools — decided on the structural path (prefix
    index / period index), never on shape coincidences."""
    top = str(getattr(path[0], "key", path[0]))
    idx = getattr(path[1], "idx", None)
    specs = cfg.prefix_layers if top == "prefix" else cfg.period
    return specs[idx].mixer


def make_clear_blocks(cfg: ModelConfig) -> Callable:
    """(caches, blocks, blocks_swa) -> caches.  Scrub the given pool
    blocks in every paged cache leaf: keys/values to 0 and positions to
    -1, so a recycled block can never leak a stale key into its next
    owner (old positions could pass the causal mask).  Full-attention
    pools take ids from ``blocks``, sliding-window pools from
    ``blocks_swa`` — the two block-id spaces are disjoint per-kind pools.
    Each vector is fixed-width int32 padded with an out-of-pool id
    (scatter mode='drop' ignores the padding), so the jit compiles once
    regardless of how many blocks a request held."""
    def clear_blocks(caches, blocks, blocks_swa):
        def clear(path, leaf):
            name = str(getattr(path[-1], "key", path[-1]))
            if name not in POOL_LEAVES:
                return leaf
            top = str(getattr(path[0], "key", path[0]))
            bdim = 1 if top == "stack" else 0
            ids = blocks_swa if _pool_mixer(cfg, path) == SWA else blocks
            idx = (slice(None),) * bdim + (ids,)
            fill = -1 if name == "pos" else 0
            return leaf.at[idx].set(jnp.asarray(fill, leaf.dtype),
                                    mode="drop")
        return jax.tree_util.tree_map_with_path(clear, caches)
    return clear_blocks


def _path_key(path) -> str:
    """Stable string key of a cache pytree path ("prefix/3/k",
    "stack/0/v", ...) — the host-side index of migration payloads."""
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


@dataclass
class RequestState:
    """A live request's complete decode state, serialized by
    ``ServingEngine.export_state`` for verified migration to another
    replica (``import_state``).

    ``pool`` / ``slot_state`` map pytree-path keys to host numpy arrays:
    each pool entry holds the request's pages for one paged cache leaf
    (page rows ordered like ``cols`` / ``cols_swa``), each slot_state
    entry one per-slot recurrent carry.  ``digests`` is the prompt's
    chain-digest trail — the content address the importer dedups against
    its own registry, so pages already resident at the destination never
    cross the wire twice.  ``checksum`` chains crc32 over the tokens,
    the position, and every payload array in deterministic order: the
    importer recomputes the chain and rejects the WHOLE transfer on any
    mismatch, so corrupted bytes are never attached to a pool."""
    req: Request
    position: int
    fingerprint: tuple
    cols: List[int]                    # attn table columns, export order
    cols_swa: List[int]                # swa ring columns, export order
    pool: Dict[str, np.ndarray]
    slot_state: Dict[str, np.ndarray]
    digests: List[int]
    checksum: int
    payload_bytes: int


def state_checksum(state: "RequestState") -> int:
    """Chained crc32 over a migration payload — tokens, position, then
    every payload array in sorted-key order.  One flipped byte anywhere
    breaks the chain, so import verification is all-or-nothing."""
    req = state.req
    c = zlib.crc32(np.asarray(req.prompt, np.int64).tobytes())
    c = zlib.crc32(np.asarray(req.generated + [req.pending],
                              np.int64).tobytes(), c)
    c = zlib.crc32(np.int64(state.position).tobytes(), c)
    for key in sorted(state.pool):
        c = zlib.crc32(np.ascontiguousarray(state.pool[key]).tobytes(), c)
    for key in sorted(state.slot_state):
        c = zlib.crc32(np.ascontiguousarray(state.slot_state[key]).tobytes(),
                       c)
    return c


def make_gather_blocks(cfg: ModelConfig) -> Callable:
    """(caches, blocks, blocks_swa, s) -> payload pytree with the SAME
    treedef as ``caches``: pool leaves become their pages at the given
    fixed-width padded block ids (full-attention pools gather ``blocks``,
    sliding-window pools ``blocks_swa``), per-slot leaves become slot
    ``s``'s row — one jitted call lifts a request's entire cache state
    (KV pages + recurrent carries) off the device.  Out-of-pool padding
    ids clamp in-bounds; the engine slices the garbage rows away."""
    def gather_blocks(caches, blocks, blocks_swa, s):
        def take(path, leaf):
            name = str(getattr(path[-1], "key", path[-1]))
            top = str(getattr(path[0], "key", path[0]))
            bdim = 1 if top == "stack" else 0
            if name in POOL_LEAVES:
                ids = blocks_swa if _pool_mixer(cfg, path) == SWA else blocks
                return leaf[(slice(None),) * bdim + (ids,)]
            if leaf.ndim <= bdim:
                return leaf
            return leaf[(slice(None),) * bdim + (s,)]
        return jax.tree_util.tree_map_with_path(take, caches)
    return gather_blocks


def make_scatter_blocks(cfg: ModelConfig) -> Callable:
    """Inverse of ``make_gather_blocks``: write a payload pytree back
    into the pools at the given block ids and into slot ``s``'s per-slot
    rows.  Scatter mode='drop' skips out-of-pool padding ids, which is
    how deduplicated pages (already resident at the destination) keep
    their payload rows from landing."""
    def scatter_blocks(caches, payload, blocks, blocks_swa, s):
        def put(path, leaf, pay):
            name = str(getattr(path[-1], "key", path[-1]))
            top = str(getattr(path[0], "key", path[0]))
            bdim = 1 if top == "stack" else 0
            if name in POOL_LEAVES:
                ids = blocks_swa if _pool_mixer(cfg, path) == SWA else blocks
                idx = (slice(None),) * bdim + (ids,)
                return leaf.at[idx].set(pay.astype(leaf.dtype), mode="drop")
            if leaf.ndim <= bdim:
                return leaf
            idx = (slice(None),) * bdim + (s,)
            return leaf.at[idx].set(pay.astype(leaf.dtype))
        return jax.tree_util.tree_map_with_path(put, caches, payload)
    return scatter_blocks


def _copy_block(caches, src, dst):
    """Copy-on-write: duplicate pool page ``src`` into ``dst`` across
    every paged cache leaf (keys, values, positions).  Used when a slot
    holding a shared prefix page is about to write into it — the write
    lands in the private copy, so shared pages are never mutated.
    ``src``/``dst`` are traced scalars: one compile covers every pair.
    (Prefix sharing is gated to models whose paged pools are all
    full-attention kind, so no per-kind routing is needed here.)"""
    def cp(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        if name not in POOL_LEAVES:
            return leaf
        top = str(getattr(path[0], "key", path[0]))
        bdim = 1 if top == "stack" else 0
        src_idx = (slice(None),) * bdim + (src,)
        dst_idx = (slice(None),) * bdim + (dst,)
        return leaf.at[dst_idx].set(leaf[src_idx])
    return jax.tree_util.tree_map_with_path(cp, caches)


class ServingEngine:
    """Fixed-slot continuous batching with **chunked prefill** and an
    optional **paged slot cache** (``paged=True`` — the default in the
    serving launchers/example; the class itself defaults to the dense
    rings, which are the bitwise reference semantics).

    Requests occupy slots; admission runs the new request's prompt through
    the shared slot cache in ``ceil(S_prompt / chunk)`` batched forward
    steps (other slots masked with position -1) instead of S single-token
    decode calls; every engine tick then decodes one token for all active
    slots in a single jitted step over the stacked slot state.

    **Paged mode** (``paged=True``): attention-family caches live in
    per-layer pools of ``num_blocks`` pages of ``page_size`` positions
    (default pool size = the dense cache's memory,
    ``slots * cache_len / page_size`` pages), addressed through a
    host-side ``(slots, ceil(cache_len / page_size))`` block table.  A
    request reserves ``ceil((prompt + max_new) / page_size)`` pages at
    admission — its OWN worst case, not the engine-wide ``cache_len`` —
    takes prompt pages immediately and one more page whenever decode
    crosses a page boundary, and frees everything when it finishes
    (freed blocks are scrubbed before recycling so no stale keys leak).
    When the pool cannot cover a reservation the queue backpressures
    (``stats["backpressure"]``) until a running request finishes; decode
    of admitted requests NEVER stalls on allocation (reservations make
    extends infallible).  Sliding-window layers draw ring pages from a
    separate exact-fit per-kind pool of ``slots * ceil(window /
    page_size)`` pages with its own block table (hybrids pass a
    ``{"attn", "swa"}`` table dict into the step); SSM/RWKV state stays
    per-slot (a recurrent carry has no sequence axis).  ``paged=False``
    selects the dense per-slot ring caches, which remain the bitwise
    reference semantics.

    **Prefix sharing** (``share_prefix=True``, the default, paged
    full-attention/MLA models): prompt-prefix pages are
    content-addressed in the allocator (chain digests + collision-proof
    check values); admission ATTACHES resident pages — table points at
    the existing block, refcount++, prefill chunks skipped, reservation
    reduced — and the first write into a shared page copies-on-write,
    so shared pages are never mutated and greedy decode stays
    bitwise-identical to the non-shared engine.  Pages physically free
    (and scrub) only at refcount zero.  See serve/README.md for the
    full page lifecycle; ``stats`` tracks ``shared_pages`` /
    ``shared_tokens`` / ``cow_copies``.  ``hold_pages`` > 0 (sharing
    engines only) additionally keeps up to that many refcount-zero
    registered pages resident in an LRU hold, so a popular prefix
    readmitted after a brief idle gap still attaches its pages — held
    pages are evicted first under ``pool_pressure`` and whenever a
    reservation needs the capacity.

    **Stateful failover** (paged engines): ``export_state(req)`` lifts a
    live request's complete decode state — generated tokens, pool page
    contents per cache kind, recurrent carries, the prompt's
    chain-digest trail, and a chained crc32 over the whole payload —
    and ``import_state`` attaches it mid-decode on another engine of the
    same model: verification first (a corrupted payload is rejected
    outright; wrong content never reaches a pool), then registry dedup
    (resident prefix pages attach by reference instead of crossing the
    wire), then one jitted scatter for the rest.  Greedy decode of a
    migrated request is bitwise-identical to never having moved.
    Crash recovery composes with it: a request carrying
    ``resume_tokens`` (the router's periodic snapshot) re-prefills
    prompt + resume as one extended prompt and resumes decode after the
    snapshot point instead of regenerating from scratch.

    **Kernel mode** (``use_kernel=True``, paged engines only): the S=1
    decode tick dispatches attention to the fused Pallas paged-decode
    kernel (``repro.kernels.paged_attention``) — the block table is
    scalar-prefetched and drives the page DMA, so the per-chunk
    gathered K/V copy of the scan path never lands in HBM.  Chunked
    prefill keeps the scan path (reference semantics) either way.

    Sampling is per-slot and in-jit: requests carry ``temperature`` /
    ``top_p`` / ``top_k`` / ``rep_penalty``; greedy (temperature 0,
    penalty 1) slots take the argmax path, bitwise-identical to the
    greedy-only engine, and sampled slots use a counter-based per-slot
    PRNG key threaded through the step as a ``(slots, 2)`` uint32 array
    — full logits never leave the device.  Repetition penalty reads a
    per-slot ``(slots, vocab)`` seen-token mask maintained in-jit from
    the step's own input tokens (prompt chunks and fed-back decode
    tokens), cleared host-side on admission.

    Per-slot positions keep the shared batched cache consistent; idle
    slots step with position -1, which every cache kind treats as a
    write/state no-op.  Cache buffers are donated to the jitted step on
    accelerator backends so the slot cache is updated in place.  Step
    inputs are assembled in numpy and shipped as one array per operand —
    never through O(slots) per-slot device ``.at[].set()`` dispatches.

    ``stats`` counts jitted forward calls (``prefill_calls`` /
    ``decode_calls``) — the admission cost of an S-token prompt is
    ``ceil(S/chunk)`` calls, which tests and benchmarks rely on — plus
    ``admitted`` and paged-pool ``backpressure`` events.
    """

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 cache_len: int = 512, chunk: int = 32, paged: bool = False,
                 page_size: int = 16, num_blocks: Optional[int] = None,
                 use_kernel: bool = False, share_prefix: bool = True,
                 hold_pages: int = 0, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.cache_len = cache_len
        self.chunk = max(1, min(chunk, cache_len))
        self.paged = paged
        self.page_size = page_size
        if use_kernel and not paged:
            raise ValueError(
                "ServingEngine: use_kernel=True requires paged=True — the "
                "fused paged-decode kernel reads the block-table pool "
                "(dense rings keep the scan path)")
        self.use_kernel = use_kernel
        # full (non-windowed) attention layers must never wrap the ring:
        # every position of prompt + generation needs a live cache entry.
        # SWA rings may wrap freely — chunked prefill attends over
        # [pre-write ring ∥ chunk], so eviction never loses in-window keys.
        specs = tuple(cfg.prefix_layers) + tuple(cfg.period)
        self._has_attn = any(s.mixer == ATTN for s in specs)
        self._has_swa = any(s.mixer == SWA for s in specs)
        self._has_recurrent = any(s.mixer in (MAMBA, RWKV) for s in specs)
        self._bounded_ctx = self._has_attn
        # prefix sharing holds only where skipping prefill compute for a
        # page leaves NO other state stale: recurrent carries would still
        # need the skipped tokens, SWA ring pages get overwritten in
        # place, and MoE capacity truncation depends on the chunk shape
        # (so a shorter tail would not be bitwise-reproducing).
        self._can_share = (paged and share_prefix and self._has_attn
                           and not self._has_swa and not self._has_recurrent
                           and not cfg.n_experts)
        if paged:
            self.n_cols = max(1, -(-cache_len // page_size))
            self.num_blocks = (num_blocks if num_blocks is not None
                               else max(1, -(-slots * cache_len // page_size)))
            # the LRU hold only pays off where pages are content-
            # addressed (sharing engines); elsewhere it would just
            # delay scrubs
            self._alloc = BlockAllocator(
                self.num_blocks,
                hold_limit=hold_pages if self._can_share else 0)
            self._ring_blocks = (swa_ring_blocks(cfg.sliding_window,
                                                 page_size, self.n_cols)
                                 if self._has_swa else 0)
            self._table = np.full((slots, self.n_cols), -1, np.int32)
            self._slot_reserved = [0] * slots
            # per-cache-kind pools: SWA layers cycle over at most
            # ring_blocks pages per slot, so their pools get their own
            # exact-fit block-id space (slots * ring_blocks pages) instead
            # of full-attention-sized ones — an exact fit can never
            # backpressure, and hybrid models stop paying full-length
            # pool memory for windowed layers.
            self.num_blocks_swa = slots * self._ring_blocks
            if self._has_swa:
                self._alloc_swa = BlockAllocator(self.num_blocks_swa)
                self._table_swa = np.full((slots, self._ring_blocks), -1,
                                          np.int32)
                self._slot_reserved_swa = [0] * slots
            self.caches = init_cache(cfg, slots, cache_len, paged=True,
                                     page_size=page_size,
                                     num_blocks=self.num_blocks,
                                     num_blocks_swa=self.num_blocks_swa)
        else:
            self.num_blocks = 0
            self.num_blocks_swa = 0
            self._table = np.zeros((slots, 1), np.int32)   # dummy, unread
            self.caches = init_cache(cfg, slots, cache_len)
        # buffer donation is a no-op on CPU and would only warn
        donate = jax.default_backend() != "cpu"
        dn = dict(donate_argnums=(1, 2)) if donate else {}
        d0 = dict(donate_argnums=(0,)) if donate else {}
        self._step_fn = jax.jit(
            make_engine_step(cfg, paged=paged,
                             use_kernel=self.use_kernel), **dn)
        self._reset_fn = jax.jit(partial(_clear_slot, skip_pools=paged), **d0)
        self._clear_blocks_fn = jax.jit(make_clear_blocks(cfg), **d0)
        self._copy_block_fn = jax.jit(_copy_block, **d0)
        # gather must NOT donate: the caches stay live after an export
        self._gather_blocks_fn = jax.jit(make_gather_blocks(cfg))
        self._scatter_blocks_fn = jax.jit(make_scatter_blocks(cfg), **d0)
        self._page_bytes_cache: Dict[str, int] = {}
        self._clear_seen_fn = jax.jit(
            lambda seen, s: seen.at[s].set(False), **d0)
        self._seen = jnp.zeros((slots, cfg.vocab_size), jnp.bool_)
        self.active: List[Optional[Request]] = [None] * slots
        self.positions = [0] * slots
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        # table columns of slot s currently mapped to a SHARED page (a
        # write into one copies first — see _ensure_blocks)
        self._slot_shared: List[set] = [set() for _ in range(slots)]
        self.stats = {"prefill_calls": 0, "decode_calls": 0, "admitted": 0,
                      "backpressure": 0, "shared_pages": 0,
                      "shared_tokens": 0, "cow_copies": 0, "preempted": 0,
                      "exported": 0, "imported": 0, "import_rejects": 0,
                      "imported_pages": 0, "deduped_pages": 0,
                      "resumed_tokens": 0}
        self._seed = seed
        self._step_seq = 0
        self._admit_seq = 0
        self._admitted_at = [0] * slots
        self._temp = np.zeros((slots,), np.float32)
        self._topp = np.ones((slots,), np.float32)
        self._topk = np.zeros((slots,), np.int32)
        self._reppen = np.ones((slots,), np.float32)

    # -- paged-pool bookkeeping (host side) -----------------------------

    def _blocks_for(self, logical_len: int) -> int:
        """Full-attention pool pages a request of total logical length
        ``logical_len`` can ever touch (its own ceil(len/page), bounded
        by the table width).  Zero for models without full-attention
        layers: SWA rings live in their own exact-fit pool that can never
        backpressure, recurrent state is per-slot."""
        if not self.paged or not self._has_attn:
            return 0
        return min(-(-logical_len // self.page_size), self.n_cols)

    def _blocks_for_swa(self, logical_len: int) -> int:
        """SWA ring pages the request will occupy (bounded by the ring)."""
        if not self.paged or not self._has_swa:
            return 0
        return min(-(-logical_len // self.page_size), self._ring_blocks)

    # -- content-addressed prefix pages ---------------------------------

    @staticmethod
    def _digest(payload) -> int:
        """Digest of one prefix page: payload chains the parent page's
        digest with this page's tokens, so equal digests (plus the
        allocator's check verification) mean equal FULL token prefixes —
        a page's KV content depends on everything before it, not just its
        own tokens.  Static so tests can monkeypatch it (e.g. to a
        constant, forcing collisions) per engine instance."""
        return hash(payload)

    def prefix_digests(self, prompt: List[int]) -> List[int]:
        """Chain digests of every FULL page of ``prompt`` — the
        content-address trail the fleet router uses for prefix-affinity
        placement (and that ``drain_requests`` pins to failover
        requeues)."""
        P = self.page_size
        out: List[int] = []
        prev = 0
        for i in range(len(prompt) // P):
            prev = self._digest((prev, tuple(prompt[i * P:(i + 1) * P])))
            out.append(prev)
        return out

    def _match_prefix(self, prompt: List[int]):
        """Resolve the longest resident shared prefix of ``prompt``.
        Returns (shared_tokens, full_hits, partial_hit):

        * full_hits — [(col, block)] for each leading FULL page resident
          in the pool (contiguous: a registrant registered all its full
          pages, so the first miss ends the chain);
        * partial_hit — (col, block, covered) when a registered
          PARTIAL page (another request's trailing prompt page) extends
          the match past the last full hit — attaching it shares the page
          first and copy-on-writes when the divergent tail is appended.
        """
        P = self.page_size
        S = len(prompt)
        hits: List[Tuple[int, int]] = []
        prev_d, prev_b = 0, -1
        m = 0
        for i in range(S // P):
            page = tuple(prompt[i * P:(i + 1) * P])
            d = self._digest((prev_d, page))
            b = self._alloc.lookup(d, (prev_b, page))
            if b is None:
                break
            hits.append((i, b))
            prev_d, prev_b = d, b
            m += 1
        partial = None
        best = 0
        for j in range(m * P + 1, min(S, (m + 1) * P) + 1):
            tail = tuple(prompt[m * P:j])
            d = self._digest((prev_d, tail, "partial"))
            b = self._alloc.lookup(d, (prev_b, tail, "partial"))
            if b is not None and j > best:
                partial, best = (m, b, j), j
        return (best if partial else m * P), hits, partial

    def shared_prefix_pages(self, prompt: List[int]) -> int:
        """How many of the request's prefix pages are resident RIGHT NOW
        (full-page hits + a trailing partial hit) — the router's
        prefix-affinity signal.  0 for engines that cannot share."""
        if not self._can_share:
            return 0
        _, hits, partial = self._match_prefix(prompt)
        return len(hits) + (1 if partial else 0)

    def prefill_calls_for(self, prompt: List[int]) -> int:
        """Jitted chunked-prefill calls admitting ``prompt`` would cost
        NOW: shared resident prefix pages are skipped, only the unshared
        tail (at least one token — the last prompt token must produce
        logits) runs through the step function."""
        S = len(prompt)
        if self._can_share:
            shared, _, _ = self._match_prefix(prompt)
            S -= min(shared, S - 1)
        return -(-S // self.chunk)

    def _register_prefix(self, s: int, prompt: List[int],
                         include_partial: bool = True) -> None:
        """Advertise slot ``s``'s freshly admitted prompt pages in the
        allocator's content registry: every FULL page under its chain
        digest, plus the trailing partial page (if any) so an
        exact-or-longer prompt can attach it and CoW on divergence.
        First registration wins; a collision (digest taken by different
        content) simply leaves our private page unadvertised.
        ``include_partial=False`` (migration import) skips the trailing
        page: a migrated slot's last prompt page already carries decode
        tokens past the prompt tail, so advertising it as exactly the
        tail would lie about its content."""
        P = self.page_size
        S = len(prompt)
        prev_d, prev_b = 0, -1
        for i in range(S // P):
            page = tuple(prompt[i * P:(i + 1) * P])
            d = self._digest((prev_d, page))
            b = int(self._table[s, i])
            self._alloc.register(d, (prev_b, page), b)
            canon = self._alloc.lookup(d, (prev_b, page))
            prev_d, prev_b = d, (canon if canon is not None else b)
        if S % P and include_partial:
            tail = tuple(prompt[(S // P) * P:])
            d = self._digest((prev_d, tail, "partial"))
            self._alloc.register(d, (prev_b, tail, "partial"),
                                 int(self._table[s, S // P]))

    def _cow(self, s: int, c: int) -> None:
        """Copy-on-write table column ``c`` of slot ``s``: take a private
        page against the slot's reservation, duplicate the shared page's
        contents on device, repoint the table, release the shared
        reference.  The shared page itself is never mutated."""
        old = int(self._table[s, c])
        assert self._alloc.refcount.get(old, 0) > 1, \
            "ServingEngine: CoW of an unshared page"
        new = self._alloc.alloc_one()
        self._slot_reserved[s] -= 1
        self.caches = self._copy_block_fn(
            self.caches, jnp.asarray(old, jnp.int32),
            jnp.asarray(new, jnp.int32))
        self._table[s, c] = new
        freed = self._alloc.free([old])
        assert not freed        # still referenced by the other holder(s)
        self._slot_shared[s].discard(c)
        self.stats["cow_copies"] += 1

    def _ensure_blocks(self, s: int, p_lo: int, p_hi: int) -> None:
        """Make the table columns that writes at positions [p_lo, p_hi]
        will touch safely writable: allocate unmapped columns; columns
        mapped to a SHARED page copy-on-write first (a divergent append
        must never mutate a page another slot still reads); an owned page
        still advertised in the content registry is deregistered before
        the append changes its content."""
        if not self.paged:
            return
        P = self.page_size
        if self._has_attn:
            for c in range(p_lo // P, p_hi // P + 1):
                b = int(self._table[s, c])
                if b < 0:
                    self._table[s, c] = self._alloc.alloc_one()
                    self._slot_reserved[s] -= 1
                elif self._can_share:
                    if self._alloc.refcount.get(b, 0) > 1:
                        self._cow(s, c)
                    else:
                        # sole holder: the append may proceed in place,
                        # but the page's advertised content is about to
                        # change — stop matching it
                        self._alloc.deregister(b)
                        self._slot_shared[s].discard(c)
        if self._has_swa:
            ring_p = self._ring_blocks * P
            if p_hi - p_lo + 1 >= ring_p:
                cols = range(self._ring_blocks)
            else:
                c0, c1 = (p_lo % ring_p) // P, (p_hi % ring_p) // P
                cols = (range(c0, c1 + 1) if c0 <= c1 else
                        list(range(c0, self._ring_blocks))
                        + list(range(c1 + 1)))
            for c in cols:
                if self._table_swa[s, c] < 0:
                    self._table_swa[s, c] = self._alloc_swa.alloc_one()
                    self._slot_reserved_swa[s] -= 1

    def _free_slot_blocks(self, s: int) -> None:
        """Drop a finished slot's page references and release unused
        reservations.  Only pages whose refcount reached ZERO return to
        the free list and get scrubbed (keys zeroed, positions -1);
        pages still shared by another slot stay live — scrubbing them
        would corrupt the other slot's cache."""
        if not self.paged:
            return
        blocks = [int(b) for b in self._table[s] if b >= 0]
        scrub: List[int] = []
        if blocks or self._slot_reserved[s]:
            scrub = self._alloc.free(blocks,
                                     unreserve=self._slot_reserved[s])
            self._slot_reserved[s] = 0
        scrub_swa: List[int] = []
        if self._has_swa:
            sblocks = [int(b) for b in self._table_swa[s] if b >= 0]
            if sblocks or self._slot_reserved_swa[s]:
                scrub_swa = self._alloc_swa.free(
                    sblocks, unreserve=self._slot_reserved_swa[s])
                self._slot_reserved_swa[s] = 0
            self._table_swa[s] = -1
        # pages the allocator evicted from its LRU hold (overflow) are
        # free-listed with stale content: scrub them with this batch
        self._scrub_blocks(scrub + self._alloc.take_scrub(), scrub_swa)
        self._table[s] = -1
        self._slot_shared[s].clear()

    def _scrub_blocks(self, scrub: List[int],
                      scrub_swa: List[int]) -> None:
        """Zero recycled pool pages (keys 0, positions -1) through the
        fixed-width jitted scrub, chunking longer lists so the jit still
        compiles once per engine."""
        wid = max(1, self._ring_blocks)
        while scrub or scrub_swa:
            part, scrub = scrub[:self.n_cols], scrub[self.n_cols:]
            part_swa, scrub_swa = scrub_swa[:wid], scrub_swa[wid:]
            pad = np.full((self.n_cols,), self.num_blocks, np.int32)
            pad[:len(part)] = part
            pad_swa = np.full((wid,), max(1, self.num_blocks_swa), np.int32)
            pad_swa[:len(part_swa)] = part_swa
            # numpy operands: jit converts once per call, nothing jnp
            # dispatches host-side in this loop
            self.caches = self._clear_blocks_fn(self.caches, pad, pad_swa)

    def _drain_scrub(self) -> None:
        """Scrub pages evicted from the allocator's LRU hold by a
        reservation or pool pressure (free-listed, stale content)."""
        if self.paged:
            self._scrub_blocks(self._alloc.take_scrub(), [])

    def _release_slot(self, s: int) -> None:
        """The ONE place a slot is vacated — shared by the finish path,
        ``drain_requests``, ``preempt_newest``, and ``export_state``:
        clear the slot, free + scrub its pages, and restore the greedy
        sampling defaults so an idle slot can't keep the
        all-greedy/no-penalty fast paths (lax.cond) switched off."""
        self.active[s] = None
        self._free_slot_blocks(s)
        self._temp[s] = 0.0
        self._topp[s] = 1.0
        self._topk[s] = 0
        self._reppen[s] = 1.0

    def _table_arg(self):
        """The block-table step operand: one array for single-kind
        engines, per-cache-kind {"attn", "swa"} tables when the pools
        have split block-id spaces."""
        if self.paged and self._has_swa:
            return {"attn": jnp.asarray(self._table),
                    "swa": jnp.asarray(self._table_swa)}
        return jnp.asarray(self._table)

    # -- occupancy / fleet hooks (read by serve.router.FleetRouter) ------

    @property
    def n_active(self) -> int:
        return sum(1 for r in self.active if r is not None)

    def admitted_requests(self) -> List[Request]:
        """Requests currently holding a slot, in ADMISSION order (slot
        index lies once slots recycle) — the order the router walks for
        migration, snapshots, and rebalance victim choice."""
        live = [s for s in range(self.slots) if self.active[s] is not None]
        live.sort(key=lambda s: self._admitted_at[s])
        return [self.active[s] for s in live]

    @property
    def pending_tokens(self) -> int:
        """Tokens this engine still has to process: queued requests cost
        their full prompt + max_new (prefill AND decode are ahead of
        them); admitted requests have paid prefill, so only their
        remaining decode tokens count.  This is the engine's current load
        term in the router's Eq. 2-style completion-time estimate."""
        tok = sum(len(r.prompt) + r.max_new for r in self.queue)
        tok += sum(r.max_new - len(r.generated)
                   for r in self.active if r is not None)
        return tok

    @property
    def pending_prefill_calls(self) -> int:
        """Jitted chunked-prefill calls still ahead of this engine (its
        own queue, shared-prefix discounts applied) — the per-call
        dispatch overhead term of the router's admission-aware ECT."""
        return sum(self.prefill_calls_for(r.prompt) for r in self.queue)

    @property
    def free_pages(self) -> int:
        """Pool pages not yet committed: free minus reservations minus
        the worst-case demand of requests already sitting in this
        engine's own queue (they WILL reserve at admission).  Dense
        engines are page-unconstrained and report a sentinel large
        enough that page checks never bind."""
        if not self.paged:
            return 1 << 30
        queued = sum(self._blocks_for(len(r.prompt) + r.max_new)
                     for r in self.queue)
        return (self._alloc.n_free + self._alloc.n_held
                - self._alloc.reserved - self._alloc.withheld - queued)

    @property
    def occupancy(self) -> dict:
        """One host-side snapshot of engine load for placement decisions
        and monitoring — no device sync."""
        return {
            "active": self.n_active,
            "queued": len(self.queue),
            "free_slots": self.slots - self.n_active,
            "pending_tokens": self.pending_tokens,
            "free_pages": self.free_pages if self.paged else None,
        }

    def can_serve(self, prompt: List[int], max_new: int) -> bool:
        """Could this engine EVER run such a request (regardless of its
        current load)?  Mirrors ``submit``'s validation without raising,
        plus a vocab bound so a heterogeneous fleet never routes token
        ids a replica's model cannot embed."""
        if not prompt or max(prompt) >= self.cfg.vocab_size:
            return False
        if self._bounded_ctx and len(prompt) + max_new > self.cache_len:
            return False
        if self.paged and self._blocks_for(len(prompt) + max_new) \
                > self.num_blocks:
            return False
        return True

    def blocks_needed(self, prompt_len: int, max_new: int) -> int:
        """Worst-case pool pages a request would reserve at admission."""
        return self._blocks_for(prompt_len + max_new)

    # -- stateful failover: verified page migration ----------------------

    def _kind_page_bytes(self, swa: bool) -> int:
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(self.caches)[0]:
            name = str(getattr(path[-1], "key", path[-1]))
            if name not in POOL_LEAVES:
                continue
            if (_pool_mixer(self.cfg, path) == SWA) != swa:
                continue
            top = str(getattr(path[0], "key", path[0]))
            bdim = 1 if top == "stack" else 0
            total += leaf.nbytes // leaf.shape[bdim]
        return total

    @property
    def page_bytes(self) -> int:
        """Bytes one full-attention pool page occupies across every
        paged cache leaf — the unit of the router's migrate-vs-reprefill
        byte estimate."""
        if not self.paged or not self._has_attn:
            return 0
        if "attn" not in self._page_bytes_cache:
            self._page_bytes_cache["attn"] = self._kind_page_bytes(False)
        return self._page_bytes_cache["attn"]

    @property
    def page_bytes_swa(self) -> int:
        """Bytes one sliding-window ring page occupies across leaves."""
        if not self.paged or not self._has_swa:
            return 0
        if "swa" not in self._page_bytes_cache:
            self._page_bytes_cache["swa"] = self._kind_page_bytes(True)
        return self._page_bytes_cache["swa"]

    def registry_digests(self) -> frozenset:
        """Digests currently resident in the content registry (LRU-held
        pages included) — the per-replica view the router gossips on
        heartbeats, so placement affinity and migrate-dedup byte
        estimates see pages registered AFTER placement decisions."""
        if not self._can_share:
            return frozenset()
        return frozenset(self._alloc._by_digest)

    def migration_fingerprint(self) -> tuple:
        """Compatibility key for stateful migration.  Page payloads are
        raw device floats, so source and destination must run the SAME
        weights (object identity — fleet replicas share one param
        pytree), the same architecture, and the same page geometry;
        anything else falls back to re-prefill."""
        return (id(self.params), self.cfg, self.paged, self.page_size,
                self.cache_len)

    def export_state(self, req: Request) -> Optional[RequestState]:
        """Serialize a LIVE request's complete decode state for verified
        migration: generated + pending tokens (they ride on the Request),
        every pool page its slot maps (per cache kind), per-slot
        recurrent carries, the prompt's chain-digest trail (the importer
        dedups against its own content registry), and a chained crc32
        over the whole payload.  The slot is released — after a
        successful ``import_state`` elsewhere the request continues
        mid-decode; if the import fails the caller falls back to
        requeue-from-prompt (the state object holds everything needed
        either way).  Returns None for dense engines or a request not
        currently admitted here."""
        if not self.paged:
            return None
        s = next((i for i in range(self.slots) if self.active[i] is req),
                 None)
        if s is None:
            return None
        cols = [c for c in range(self.n_cols) if self._table[s, c] >= 0]
        blocks = [int(self._table[s, c]) for c in cols]
        cols_swa, blocks_swa = [], []
        if self._has_swa:
            cols_swa = [c for c in range(self._ring_blocks)
                        if self._table_swa[s, c] >= 0]
            blocks_swa = [int(self._table_swa[s, c]) for c in cols_swa]
        pad = np.full((self.n_cols,), self.num_blocks, np.int32)
        pad[:len(blocks)] = blocks
        wid = max(1, self._ring_blocks)
        pad_swa = np.full((wid,), max(1, self.num_blocks_swa), np.int32)
        pad_swa[:len(blocks_swa)] = blocks_swa
        payload = self._gather_blocks_fn(self.caches, jnp.asarray(pad),
                                         jnp.asarray(pad_swa),
                                         jnp.asarray(s, jnp.int32))
        pool: Dict[str, np.ndarray] = {}
        slot_state: Dict[str, np.ndarray] = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(payload)[0]:
            key = _path_key(path)
            name = str(getattr(path[-1], "key", path[-1]))
            bdim = 1 if key.startswith("stack") else 0
            arr = np.asarray(leaf)
            if name in POOL_LEAVES:
                k = (len(cols_swa)
                     if _pool_mixer(self.cfg, path) == SWA else len(cols))
                pool[key] = arr[(slice(None),) * bdim + (slice(0, k),)]
            else:
                slot_state[key] = arr
        state = RequestState(
            req=req, position=self.positions[s],
            fingerprint=self.migration_fingerprint(),
            cols=cols, cols_swa=cols_swa, pool=pool, slot_state=slot_state,
            digests=self.prefix_digests(req.prompt), checksum=0,
            payload_bytes=(sum(a.nbytes for a in pool.values())
                           + sum(a.nbytes for a in slot_state.values())))
        state.checksum = state_checksum(state)
        req.prefix_digests = state.digests
        self._release_slot(s)
        self.stats["exported"] += 1
        return state

    def import_state(self, state: RequestState) -> bool:
        """Attach a migrated request mid-decode.  Verification comes
        FIRST: the payload's checksum chain is recomputed and any
        mismatch rejects the whole transfer before a byte reaches the
        pool — unverified content is never served.  Then the prompt's
        full prefix pages are deduplicated against the local content
        registry (resident pages attach by reference; their bitwise
        equality follows from the chain-digest + check-value induction),
        the rest land in freshly allocated pages via one jitted scatter,
        and the slot's positions / sampling params / seen mask are
        restored.  Returns False — engine state untouched — when no slot
        or pages are free, the fingerprint mismatches, or verification
        fails."""
        req = state.req
        if not self.paged \
                or state.fingerprint != self.migration_fingerprint():
            return False
        s = next((i for i in range(self.slots) if self.active[i] is None),
                 None)
        if s is None:
            return False
        if state_checksum(state) != state.checksum:
            self.stats["import_rejects"] += 1
            return False
        S = len(req.prompt)
        hits: List[Tuple[int, int]] = []
        if self._can_share:
            _, hits, _ = self._match_prefix(req.prompt)
        resident = {c: b for (c, b) in hits if c in set(state.cols)}
        need = self._blocks_for(S + req.max_new) - len(resident)
        if not self._alloc.reserve(need):
            return False
        self._slot_reserved[s] = need
        need_swa = self._blocks_for_swa(S + req.max_new)
        if need_swa:
            ok = self._alloc_swa.reserve(need_swa)
            assert ok   # exact-fit pool: slots * ring_blocks
            self._slot_reserved_swa[s] = need_swa
        self._drain_scrub()
        self.caches = self._reset_fn(self.caches, s)
        self._seen = self._clear_seen_fn(self._seen, s)
        # map table columns: attach deduped pages by reference; fresh
        # pages for the rest.  ``ids[j]`` pairs with payload row j —
        # deduped columns keep the out-of-pool padding id so the
        # scatter (mode='drop') skips their rows entirely.
        ids = np.full((self.n_cols,), self.num_blocks, np.int32)
        written = 0
        for j, c in enumerate(state.cols):
            if c in resident:
                b = resident[c]
                self._alloc.share(b)
                self._table[s, c] = b
                self._slot_shared[s].add(c)
            else:
                b = self._alloc.alloc_one()
                self._slot_reserved[s] -= 1
                self._table[s, c] = b
                ids[j] = b
                written += 1
        wid = max(1, self._ring_blocks)
        ids_swa = np.full((wid,), max(1, self.num_blocks_swa), np.int32)
        for j, c in enumerate(state.cols_swa):
            b = self._alloc_swa.alloc_one()
            self._slot_reserved_swa[s] -= 1
            self._table_swa[s, c] = b
            ids_swa[j] = b

        def build(path, leaf):
            key = _path_key(path)
            name = str(getattr(path[-1], "key", path[-1]))
            top = str(getattr(path[0], "key", path[0]))
            bdim = 1 if top == "stack" else 0
            if name in POOL_LEAVES:
                swa = _pool_mixer(self.cfg, path) == SWA
                rows = wid if swa else self.n_cols
                src = state.pool[key]
                shape = list(leaf.shape)
                shape[bdim] = rows
                out = np.zeros(shape, src.dtype)
                k = src.shape[bdim]
                out[(slice(None),) * bdim + (slice(0, k),)] = src
                return jnp.asarray(out)
            if leaf.ndim <= bdim:
                return leaf
            return jnp.asarray(state.slot_state[key])

        payload = jax.tree_util.tree_map_with_path(build, self.caches)
        self.caches = self._scatter_blocks_fn(
            self.caches, payload, jnp.asarray(ids), jnp.asarray(ids_swa),
            jnp.asarray(s, jnp.int32))
        self.active[s] = req
        self._admit_seq += 1
        self._admitted_at[s] = self._admit_seq
        self.positions[s] = state.position
        self._temp[s] = req.temperature
        self._topp[s] = req.top_p
        self._topk[s] = req.top_k
        self._reppen[s] = req.rep_penalty
        if req.rep_penalty != 1.0:
            # the in-jit seen mask is maintained from step inputs, which
            # this engine never saw: rebuild it from prompt + generated
            row = np.zeros((self.cfg.vocab_size,), bool)
            row[np.asarray(req.prompt + req.generated, np.int64)] = True
            self._seen = self._seen.at[s].set(jnp.asarray(row))
        if self._can_share:
            self._register_prefix(s, req.prompt, include_partial=False)
        self.stats["imported"] += 1
        self.stats["imported_pages"] += written + len(state.cols_swa)
        self.stats["deduped_pages"] += len(resident)
        return True

    def drain_requests(self) -> List[Request]:
        """Harvest every live request in SUBMISSION order — admitted
        slots by admission sequence (slot index lies once slots have
        been recycled), then the engine queue, which is FIFO and
        strictly younger than anything admitted — for re-queueing on
        another replica.  Cache/pages are assumed lost with the replica,
        so each request is reset to re-prefill from its prompt:
        generated tokens are discarded, never silently kept or dropped.
        The engine itself is left empty (slots idle, pages freed,
        sampling params back to greedy defaults).  Each request keeps its
        prefix-page digest trail (``prefix_digests``) so the router's
        failover requeue can still steer it toward a replica already
        holding (or about to admit) the same shared prefix."""
        out: List[Request] = []
        admitted = sorted((s for s in range(self.slots)
                           if self.active[s] is not None),
                          key=lambda s: self._admitted_at[s])
        for s in admitted:
            req = self.active[s]
            self._release_slot(s)
            out.append(req)
        out.extend(self.queue)
        self.queue = []
        for req in out:
            req.generated = []
            req.pending = -1
            req.done = False
            req.prefix_digests = self.prefix_digests(req.prompt)
        return out

    def preempt_newest(self) -> Optional[Request]:
        """Evict the YOUNGEST live request — the engine queue's tail if
        any (it holds no pages yet), else the most recently admitted
        slot — resetting it to re-prefill from its prompt exactly like
        ``drain_requests`` (generated tokens discarded, pages freed and
        scrubbed, prefix digests stamped so the victim re-shares its
        prefix wherever it lands).  Returns the victim, or None when the
        engine is idle.  The router uses this to satisfy a held
        head-of-line request's worst-case reservation: preempting newest
        keeps the loss (tokens already decoded) minimal and FIFO fairness
        intact — the head is by construction older than anything
        admitted after it."""
        if self.queue:
            req = self.queue.pop()
        else:
            live = [s for s in range(self.slots) if self.active[s] is not None]
            if not live:
                return None
            s = max(live, key=lambda s: self._admitted_at[s])
            req = self.active[s]
            self._release_slot(s)
        req.generated = []
        req.pending = -1
        req.done = False
        req.prefix_digests = self.prefix_digests(req.prompt)
        self.stats["preempted"] += 1
        return req

    def set_pool_pressure(self, pages: int) -> None:
        """Fault injection (``faults.pool_pressure``): withhold ``pages``
        full-attention pool pages from NEW admissions, as if a co-tenant
        grabbed the memory.  Reservation-backed decode of admitted
        requests is untouched — pressure can only backpressure the
        queue, never crash in-flight work.  ``0`` restores the full
        pool.  No-op for dense engines and for models without
        full-attention paged pools (the SWA ring pool is exact-fit by
        construction and must never be squeezed).  Pages idling in the
        LRU hold are surrendered first — the hold is a cache, not a
        commitment."""
        if not self.paged or not self._has_attn:
            return
        pages = max(0, int(pages))
        if pages:
            self._alloc.evict_held(pages)
            self._drain_scrub()
        self._alloc.withheld = pages

    # -- request intake --------------------------------------------------

    def submit(self, req: Request) -> None:
        if not req.prompt:
            raise ValueError(
                f"ServingEngine: request {req.req_id} has an empty prompt — "
                f"at least one prompt token is required to seed decoding")
        if self._bounded_ctx and len(req.prompt) + req.max_new > self.cache_len:
            # only full-attention caches bound the context: SWA rings wrap
            # exactly under the window mask, recurrent state has no length
            raise ValueError(
                f"ServingEngine: request {req.req_id} needs "
                f"{len(req.prompt)} prompt + {req.max_new} new tokens but "
                f"cache_len={self.cache_len}; full-attention caches must "
                f"not wrap (raise cache_len or lower max_new)")
        if self.paged:
            need = self._blocks_for(len(req.prompt) + req.max_new)
            if need > self.num_blocks:
                raise ValueError(
                    f"ServingEngine: request {req.req_id} needs {need} cache "
                    f"pages but the pool has only {self.num_blocks} — it "
                    f"could never be admitted (raise num_blocks)")
        self.queue.append(req)

    def warmup(self) -> None:
        """Compile the two engine shapes ahead of serving: the chunked-
        prefill step (slots, chunk) and the decode tick (slots, 1).  Runs
        them with every position masked (-1), which is a cache no-op, so
        warmup never perturbs engine state."""
        for C in sorted({self.chunk, 1}):
            toks = np.zeros((self.slots, C), np.int32)
            pos = np.full((self.slots, C), -1, np.int32)
            _, self.caches = self._call_step(toks, pos)
        # compile the reset against a FREE slot only (resetting it is
        # harmless — admission resets again); never touch a live one
        free = [s for s in range(self.slots) if self.active[s] is None]
        if free:
            self.caches = self._reset_fn(self.caches, free[-1])
        if self.paged:
            # all-padding block vectors: scrub is a compiled no-op
            pad = np.full((self.n_cols,), self.num_blocks, np.int32)
            pad_swa = np.full((max(1, self._ring_blocks),),
                              max(1, self.num_blocks_swa), np.int32)
            self.caches = self._clear_blocks_fn(self.caches,
                                                jnp.asarray(pad),
                                                jnp.asarray(pad_swa))
        jax.block_until_ready(self.caches)

    # -- the serving loop ------------------------------------------------

    def _call_step(self, toks: np.ndarray, pos: np.ndarray):
        """One jitted engine step; host-side operands (numpy) convert to
        device arrays ONCE here.  The per-slot PRNG keys are counter-based
        (slot seed, step counter), so sampling streams are deterministic
        and never leave host control."""
        keys = np.empty((self.slots, 2), np.uint32)
        keys[:, 0] = np.arange(self._seed, self._seed + self.slots,
                               dtype=np.uint32)
        keys[:, 1] = np.uint32(self._step_seq)
        self._step_seq += 1
        nxt, self.caches, self._seen = self._step_fn(
            self.params, self.caches, self._seen, jnp.asarray(toks),
            jnp.asarray(pos), self._table_arg(), jnp.asarray(keys),
            jnp.asarray(self._temp), jnp.asarray(self._topp),
            jnp.asarray(self._topk), jnp.asarray(self._reppen))
        return nxt, self.caches

    def _admit(self) -> None:
        """Chunked-prefill admission: reserve the request's worst-case
        pages (paged mode; insufficient pool = backpressure, the queue
        stays FIFO), reset the slot's per-slot state, then walk the
        prompt through the cache ``chunk`` tokens per jitted step (other
        slots masked with position -1).  The final chunk may be shorter —
        it compiles once per distinct remainder length.

        **Prefix-sharing fast path** (content-addressed pools): prompt
        pages already resident — registered by an earlier admission whose
        prompt shares this one's prefix — are ATTACHED (table points at
        the existing page, refcount++) instead of reserved and
        re-prefilled; only the unshared tail runs through the jitted
        steps.  At least the LAST prompt token always recomputes (its
        logits seed decoding), so a fully resident prompt still costs one
        short chunk — and copy-on-writes the page it lands in.  The
        skipped pages are also excluded from the up-front reservation,
        which is what raises peak concurrency at equal pool memory."""
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue[0]
                # snapshot restore: prefill prompt + resume tokens as ONE
                # extended prompt (chunked prefill writes KV bitwise-equal
                # to the decode that first produced it), then restore
                # ``generated`` below — only tokens decoded after the
                # router's snapshot are re-decoded
                resume = list(req.resume_tokens or ())
                full = req.prompt + resume
                S = len(full)
                # total logical length stays prompt + max_new: resumed
                # tokens count against the generation budget
                total = S + req.max_new - len(resume)
                shared_tok, hits, partial = (
                    self._match_prefix(full) if self._can_share
                    else (0, [], None))
                start = min(shared_tok, S - 1)
                if self.paged:
                    # reserve only unshared pages — but a shared page the
                    # tail will write into (the partial hit, or the last
                    # full hit when the whole prompt matched) still needs
                    # a private page for its copy-on-write
                    untouched = sum(1 for (i, _) in hits
                                    if (i + 1) * self.page_size <= start)
                    need = self._blocks_for(total) - untouched
                    if not self._alloc.reserve(need):
                        self.stats["backpressure"] += 1
                        break          # FIFO: later requests wait too
                    self._slot_reserved[s] = need
                    self._drain_scrub()
                    need_swa = self._blocks_for_swa(total)
                    if need_swa:
                        ok = self._alloc_swa.reserve(need_swa)
                        assert ok   # exact-fit pool: slots * ring_blocks
                        self._slot_reserved_swa[s] = need_swa
                self.queue.pop(0)
                self.active[s] = req
                self._admit_seq += 1
                self._admitted_at[s] = self._admit_seq
                self.caches = self._reset_fn(self.caches, s)
                self._seen = self._clear_seen_fn(self._seen, s)
                self._temp[s] = req.temperature
                self._topp[s] = req.top_p
                self._topk[s] = req.top_k
                self._reppen[s] = req.rep_penalty
                for (c, b) in hits:
                    self._table[s, c] = b
                    self._alloc.share(b)
                    self._slot_shared[s].add(c)
                if partial is not None:
                    c, b, _ = partial
                    self._table[s, c] = b
                    self._alloc.share(b)
                    self._slot_shared[s].add(c)
                self.stats["shared_pages"] += \
                    len(hits) + (1 if partial else 0)
                self.stats["shared_tokens"] += start
                prompt = np.asarray(full, np.int32)
                nxt = None
                for c0 in range(start, S, self.chunk):
                    piece = prompt[c0:c0 + self.chunk]
                    C = len(piece)
                    self._ensure_blocks(s, c0, c0 + C - 1)
                    toks = np.zeros((self.slots, C), np.int32)
                    toks[s] = piece
                    pos = np.full((self.slots, C), -1, np.int32)
                    pos[s] = np.arange(c0, c0 + C, dtype=np.int32)
                    nxt, self.caches = self._call_step(toks, pos)
                    self.stats["prefill_calls"] += 1
                if self._can_share:
                    self._register_prefix(s, full)
                self.positions[s] = S
                # the extended prompt's last logits ARE the decode logits
                # at that position (chunked-prefill parity), so greedy
                # resume continues exactly where the snapshot left off
                req.pending = int(nxt[s, -1])
                if resume:
                    req.generated = resume
                    req.resume_tokens = None
                    self.stats["resumed_tokens"] += len(resume)
                self.stats["admitted"] += 1

    def tick(self) -> int:
        """One engine iteration: feed each active slot's pending token,
        emit it, and compute the next — a single jitted decode step over
        all slots.  Returns #active slots."""
        self._admit()
        act = [s for s in range(self.slots) if self.active[s] is not None]
        if not act:
            return 0
        toks = np.zeros((self.slots, 1), np.int32)
        pos = np.full((self.slots, 1), -1, np.int32)
        for s in act:
            self._ensure_blocks(s, self.positions[s], self.positions[s])
            toks[s, 0] = self.active[s].pending
            pos[s, 0] = self.positions[s]
        nxt, self.caches = self._call_step(toks, pos)
        self.stats["decode_calls"] += 1
        for s in act:
            req = self.active[s]
            req.generated.append(req.pending)
            req.pending = int(nxt[s, 0])
            self.positions[s] += 1
            if len(req.generated) >= req.max_new:
                req.done = True
                self.finished.append(req)
                self._release_slot(s)
        return len(act)

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        for _ in range(max_ticks):
            if not self.tick() and not self.queue:
                break
        return self.finished
