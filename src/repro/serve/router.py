"""Fleet router: broker-routed multi-engine serving (paper §3.2 + §3.8).

``FleetRouter`` is the bridge between the repo's two halves: the
decentralized control plane (``core.broker.Broker`` — membership,
heartbeats, a backup pool, speed-matched replacement drafting) and the
serving data plane (``serve.engine.ServingEngine`` — chunked prefill,
paged slot cache, fused decode kernel).  It owns N engine replicas, each
bound to a simulated ``CompNode`` device (``perfmodel.DEVICE_CATALOG``),
pulls from ONE shared FIFO request queue, and places each request on the
replica minimizing the Eq. 2-style estimated completion time

    ECT(r, p) = (pending_tokens(p) + prompt + max_new
                 + prefill_call_cost * (pending_prefill_calls(p)
                                        + prefill_calls_for(r)
                                        + queued(p)))
                * flops_per_token(p) / CompNode.speed(p)

admission-aware: every jitted chunked-prefill call still ahead of the
replica (its queue's, prefix-sharing discounts applied, plus this
request's own tail) costs ``prefill_call_cost`` token-equivalents of
dispatch overhead, and each queued request one admission's worth of
service latency.  Replicas within ``tie_eps`` of the best ECT are a
near-tie, broken toward PREFIX AFFINITY — the replica already holding
(or about to admit) the request's shared prompt-prefix pages — then by
lowest replica id (fully deterministic).  Placement is subject to the
replica's free paged blocks (a request is only dispatched to a replica
whose pool can cover its worst-case reservation on top of everything
already queued there; otherwise it waits at the head of the shared
queue — FIFO is never reordered).  A head request that no LIVE replica
could ever run (heterogeneous fleets: vocab/context/pool gating) drafts
the fastest capable standby from the backup pool immediately instead of
waiting for a failure that may never come.

Fault tolerance reuses the broker verbatim: every replica's node is
registered ``active``, every standby replica's node ``backup``.  A
heartbeat round can kill a replica mid-decode; the broker then drafts
the backup whose device speed best matches the dead one, the router
activates the corresponding standby engine, and the dead replica's
in-flight requests (admitted slots AND its internal queue) are re-queued
at the FRONT of the shared queue from their prompts — the KV/pages died
with the replica, so they re-prefill from scratch; nothing is ever
silently dropped.  Drained requests keep their prefix digests
(``drain_requests`` stamps them), so same-prefix victims still
co-locate by affinity and re-share their prefix pages on the
survivor.  Requests on unaffected replicas are untouched (slot
isolation keeps their greedy decode bitwise-identical to a no-failure
run).

Replicas may be heterogeneous in BOTH dimensions: different simulated
devices (speed skews placement toward fast peers) and different
(params, cfg) models (``can_serve`` gates by vocab bound, context
length, and pool size, so a request only routes to replicas whose model
can actually run it).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax

from repro.core.broker import Broker
from repro.core.perfmodel import (DEVICE_CATALOG, LINK_REGIMES, CompNode,
                                  DeviceSpec, LinkSpec)
from repro.serve.engine import Request, ServingEngine

DeviceLike = Union[str, DeviceSpec, CompNode]


def sim_node(device: DeviceLike, *,
             link: Optional[LinkSpec] = None, lam: float = 0.75,
             reliability: float = 0.999) -> CompNode:
    """A simulated provider for a replica: catalog name / spec -> CompNode
    (node_id is assigned by the broker at registration)."""
    if isinstance(device, CompNode):
        return device
    spec = DEVICE_CATALOG[device] if isinstance(device, str) else device
    return CompNode(-1, spec, link or LINK_REGIMES["lan_10gbps"], lam=lam,
                    reliability=reliability)


def _flops_per_token(engine: ServingEngine) -> float:
    """Analytic per-token cost of a replica's model: the standard
    2 * params FLOPs/token estimate, read off the replica's own param
    pytree so heterogeneous-model fleets cost each replica correctly."""
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(engine.params))
    return 2.0 * float(n_params)


@dataclass
class Replica:
    """One engine bound to one simulated device."""
    replica_id: int
    engine: ServingEngine
    node: CompNode
    flops_per_token: float
    alive: bool = True
    served: List[int] = field(default_factory=list)   # completed req_ids
    _harvested: int = 0        # prefix of engine.finished already collected


class FleetRouter:
    """N serving replicas + standby spares behind one FIFO queue, with
    broker membership/failover.  See the module docstring for semantics.

    ``replicas`` / ``standby``: sequences of ``(engine, device)`` pairs,
    ``device`` a ``DEVICE_CATALOG`` name, a ``DeviceSpec``, or a
    pre-built ``CompNode`` (whose ``reliability`` drives the seeded
    heartbeat failure process).

    ``stats`` counts ``placed`` dispatches, ``completed`` requests,
    replica ``failures``, ``requeued`` in-flight requests, backup-pool
    ``replacements``, and head-of-line ``held`` ticks (no replica had
    pool room for the queue head).  ``placements`` records every
    req_id -> [replica_id, ...] dispatch history (len > 1 = re-queued
    after a failure).
    """

    def __init__(self, replicas: Sequence[Tuple[ServingEngine, DeviceLike]],
                 standby: Sequence[Tuple[ServingEngine, DeviceLike]] = (),
                 *, seed: int = 0, heartbeat_s: float = 10.0,
                 prefill_call_cost: float = 4.0, tie_eps: float = 0.02):
        if not replicas:
            raise ValueError("FleetRouter: at least one replica required")
        # admission-aware ECT: each outstanding jitted prefill call costs
        # this many token-equivalents of dispatch overhead on top of its
        # tokens, and each queued request one admission's worth of
        # service latency.  tie_eps is the relative ECT band treated as a
        # near-tie, broken toward prefix affinity then replica id.
        self.prefill_call_cost = prefill_call_cost
        self.tie_eps = tie_eps
        self.broker = Broker(seed=seed, heartbeat_s=heartbeat_s)
        self.replicas: List[Replica] = []
        self._standby: Dict[int, Replica] = {}      # node_id -> Replica
        self._by_node: Dict[int, Replica] = {}
        rid = 0
        seen_engines: set = set()
        for pool, pairs in (("active", replicas), ("backup", standby)):
            for engine, device in pairs:
                if id(engine) in seen_engines:
                    raise ValueError(
                        "FleetRouter: the same ServingEngine object was "
                        "passed for two replicas — each replica needs its "
                        "own engine (they hold independent slot caches)")
                seen_engines.add(id(engine))
                node = sim_node(device)
                self.broker.register(node, pool=pool)
                rep = Replica(rid, engine, node, _flops_per_token(engine),
                              alive=(pool == "active"))
                self._by_node[node.node_id] = rep
                if pool == "active":
                    self.replicas.append(rep)
                else:
                    self._standby[node.node_id] = rep
                rid += 1
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.placements: Dict[int, List[int]] = {}
        self._submit_order: Dict[int, int] = {}     # req_id -> arrival seq
        self.stats = {"placed": 0, "completed": 0, "failures": 0,
                      "requeued": 0, "replacements": 0, "held": 0}

    # -- membership ------------------------------------------------------

    def live_replicas(self) -> List[Replica]:
        return [r for r in self.replicas if r.alive]

    def _servable_somewhere(self, req: Request) -> bool:
        pool = self.live_replicas() + list(self._standby.values())
        return any(r.engine.can_serve(req.prompt, req.max_new) for r in pool)

    # -- intake + placement ----------------------------------------------

    def submit(self, req: Request) -> None:
        if not self._servable_somewhere(req):
            raise ValueError(
                f"FleetRouter: no replica (live or standby) can ever serve "
                f"request {req.req_id} (prompt={len(req.prompt)} tokens, "
                f"max_new={req.max_new}) — check vocab/cache_len/pool sizes")
        self._submit_order.setdefault(req.req_id, len(self._submit_order))
        self.queue.append(req)

    def _ect(self, rep: Replica, req: Request) -> float:
        """Eq. 2-style estimated completion time of ``req`` on ``rep``,
        admission-aware: beyond the token count (outstanding work plus
        this request), every jitted chunked-prefill call still ahead —
        the replica's queue, prefix-sharing discounts applied, plus this
        request's own ``ceil(tail/chunk)`` — costs
        ``prefill_call_cost`` token-equivalents of dispatch overhead,
        and each already-queued request one more admission's worth of
        service latency.  Two replicas with equal token backlogs no
        longer tie when one of them has the backlog fragmented across
        many short prompts (more calls, slower wall clock)."""
        eng = rep.engine
        tokens = eng.pending_tokens + len(req.prompt) + req.max_new
        calls = eng.pending_prefill_calls + eng.prefill_calls_for(req.prompt)
        tokens += self.prefill_call_cost * (calls + len(eng.queue))
        return tokens * rep.flops_per_token / rep.node.speed

    def _affinity(self, rep: Replica, req: Request) -> int:
        """Prefix-affinity score of placing ``req`` on ``rep``: resident
        shared prefix pages the engine could attach RIGHT NOW, or — when
        the pages died with a failed replica — the longest common
        prefix-digest run with a request already queued on ``rep`` (the
        pages will be registered when that request admits, so
        co-locating still converts to sharing).  Digest trails come from
        ``drain_requests`` for failover requeues and are recomputed from
        the prompt otherwise."""
        eng = rep.engine
        pages = eng.shared_prefix_pages(req.prompt)
        mine = (req.prefix_digests if req.prefix_digests is not None
                else eng.prefix_digests(req.prompt))
        for other in eng.queue:
            theirs = (other.prefix_digests if other.prefix_digests is not None
                      else eng.prefix_digests(other.prompt))
            common = 0
            for a, b in zip(mine, theirs):
                if a != b:
                    break
                common += 1
            pages = max(pages, common)
        return pages

    def _draft_capable_standby(self, req: Request) -> Optional[Replica]:
        """No LIVE replica can ever serve ``req``: activate the fastest
        standby whose model can (waiting for a failure to draft it would
        hold the queue head forever)."""
        cands = [r for r in self._standby.values()
                 if r.engine.can_serve(req.prompt, req.max_new)]
        if not cands:
            return None
        rep = max(cands, key=lambda r: r.node.speed)
        self.broker.activate_backup(
            rep.node.node_id, f"req {req.req_id} unservable on live fleet")
        self._standby.pop(rep.node.node_id)
        rep.alive = True
        self.replicas.append(rep)
        self.stats["replacements"] += 1
        return rep

    def _dispatch(self) -> None:
        """Place queued requests, FIFO: the head request goes to the
        min-ECT live replica whose paged pool can still cover its
        worst-case reservation; if none currently can (but one could
        later), the head WAITS — later requests are not reordered past
        it.  A head that no live replica could EVER run drafts a capable
        standby from the backup pool, or raises (never a silent drop)."""
        while self.queue:
            req = self.queue[0]
            able = [r for r in self.live_replicas()
                    if r.engine.can_serve(req.prompt, req.max_new)]
            if not able:
                drafted = self._draft_capable_standby(req)
                if drafted is None:
                    raise RuntimeError(
                        f"FleetRouter: request {req.req_id} became "
                        f"unservable after fleet churn (no live or standby "
                        f"replica can run it)")
                able = [drafted]
            ready = [r for r in able
                     if r.engine.free_pages
                     >= r.engine.blocks_needed(len(req.prompt), req.max_new)]
            if not ready:
                self.stats["held"] += 1
                return
            # near-tie break toward prefix affinity: replicas within
            # tie_eps of the best ECT are effectively interchangeable on
            # load, so prefer the one already holding (or about to admit)
            # the request's shared prefix pages; exact ties fall back to
            # the lowest replica id — fully deterministic
            ects = {r.replica_id: self._ect(r, req) for r in ready}
            floor = min(ects.values())
            band = [r for r in ready
                    if ects[r.replica_id] <= floor * (1.0 + self.tie_eps)]
            best = min(band, key=lambda r: (-self._affinity(r, req),
                                            ects[r.replica_id],
                                            r.replica_id))
            self.queue.pop(0)
            best.engine.submit(req)
            self.placements.setdefault(req.req_id, []).append(best.replica_id)
            self.stats["placed"] += 1

    # -- failure handling -------------------------------------------------

    def _harvest(self, rep: Replica) -> None:
        for req in rep.engine.finished[rep._harvested:]:
            self.finished.append(req)
            rep.served.append(req.req_id)
            self.stats["completed"] += 1
        rep._harvested = len(rep.engine.finished)

    def _on_death(self, node_id: int) -> None:
        rep = self._by_node.get(node_id)
        if rep is None or not rep.alive:
            return
        self._harvest(rep)                 # finished outputs survive
        rep.alive = False
        requeue = rep.engine.drain_requests()
        self.queue[:0] = requeue
        # restore GLOBAL submission order: with several replicas dying in
        # one heartbeat round (or across rounds before redispatch), the
        # per-replica prepends alone would interleave newer requests
        # ahead of older ones
        self.queue.sort(key=lambda r: self._submit_order[r.req_id])
        self.stats["failures"] += 1
        self.stats["requeued"] += len(requeue)
        sub = self.broker.draft_backup(node_id)
        if sub is not None:
            drafted = self._standby.pop(sub.node_id)
            drafted.alive = True
            self.replicas.append(drafted)
            self.stats["replacements"] += 1

    def heartbeat_round(self) -> List[int]:
        """One broker ping-pong round over the replica nodes: each node
        fails with (1 - reliability), seeded — a failure mid-decode kills
        the replica, requeues its in-flight requests from their prompts,
        and drafts a speed-matched standby.  Returns dead node ids."""
        dead = self.broker.heartbeat_round()
        for nid in dead:
            self._on_death(nid)
        return dead

    def fail_replica(self, replica_id: int) -> None:
        """Deterministic failure injection (tests/examples): kill one
        replica through the same broker quit -> drain -> requeue ->
        draft path the heartbeat uses."""
        rep = next(r for r in self.replicas if r.replica_id == replica_id)
        self.broker.quit(rep.node.node_id, graceful=False)
        self._on_death(rep.node.node_id)

    # -- the serving loop -------------------------------------------------

    def tick(self) -> int:
        """One fleet iteration: dispatch the shared queue, tick every
        live replica, harvest finished requests.  Returns the number of
        active slots across the fleet."""
        self._dispatch()
        n = 0
        for rep in self.live_replicas():
            n += rep.engine.tick()
            self._harvest(rep)
        return n

    def outstanding(self) -> int:
        """Requests submitted but not yet completed (shared queue +
        every live replica's queue and slots)."""
        n = len(self.queue)
        for rep in self.live_replicas():
            n += len(rep.engine.queue) + rep.engine.n_active
        return n

    def run(self, max_ticks: int = 10_000,
            heartbeat_every: int = 0) -> List[Request]:
        """Serve until every submitted request completed (or
        ``max_ticks``).  ``heartbeat_every`` > 0 runs a broker heartbeat
        round every that-many ticks, so seeded failures strike
        mid-decode."""
        for t in range(max_ticks):
            if heartbeat_every and t > 0 and t % heartbeat_every == 0:
                self.heartbeat_round()
            n = self.tick()
            if n == 0 and not self.queue:
                break
        if self.outstanding():
            # never return partial results as success
            why = ("fleet died (backup pool exhausted)"
                   if not self.live_replicas() else f"max_ticks={max_ticks}")
            raise RuntimeError(
                f"FleetRouter: {self.outstanding()} requests outstanding "
                f"after {why}")
        return self.finished
