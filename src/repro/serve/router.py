"""Fleet router: broker-routed multi-engine serving (paper §3.2 + §3.8).

``FleetRouter`` is the bridge between the repo's two halves: the
decentralized control plane (``core.broker.Broker`` — membership,
heartbeats, a backup pool, speed-matched replacement drafting) and the
serving data plane (``serve.engine.ServingEngine`` — chunked prefill,
paged slot cache, fused decode kernel).  It owns N engine replicas, each
bound to a simulated ``CompNode`` device (``perfmodel.DEVICE_CATALOG``),
pulls from ONE shared FIFO request queue, and places each request on the
replica minimizing the Eq. 2-style estimated completion time

    ECT(r, p) = (pending_tokens(p) + prompt + max_new
                 + prefill_call_cost * (pending_prefill_calls(p)
                                        + prefill_calls_for(r)
                                        + queued(p)))
                * flops_per_token(p) / CompNode.speed(p)
                * lat_ewma(p)

admission-aware: every jitted chunked-prefill call still ahead of the
replica (its queue's, prefix-sharing discounts applied, plus this
request's own tail) costs ``prefill_call_cost`` token-equivalents of
dispatch overhead, and each queued request one admission's worth of
service latency.  ``lat_ewma`` is the replica's observed tick-latency
EWMA (1.0 when healthy), so a straggling replica's ECT inflates by
exactly how slow it has actually been.  Replicas within ``tie_eps`` of
the best ECT are a near-tie, broken toward PREFIX AFFINITY — the replica
already holding (or about to admit) the request's shared prompt-prefix
pages — then by lowest replica id (fully deterministic).  Placement is
subject to the replica's free paged blocks (a request is only dispatched
to a replica whose pool can cover its worst-case reservation on top of
everything already queued there; otherwise it waits at the head of the
shared queue — FIFO is never reordered).  A head request that no LIVE
replica could ever run (heterogeneous fleets: vocab/context/pool gating)
drafts the fastest capable standby from the backup pool immediately
instead of waiting for a failure that may never come.

**Degraded modes** (see ``serve.faults`` for the injection plane): the
failure model is no longer binary.  A replica whose tick-latency EWMA
crosses ``drain_factor`` is **soft-drained** — its in-flight work is
requeued via the digest-preserving ``drain_requests()`` so victims
re-share their prefixes on healthier replicas — and receives no new work
until its EWMA recovers.  A **partitioned** replica is unreachable (no
dispatch, no engine ticks, no harvest) but its engine state is RETAINED:
on heal, in-flight decode resumes mid-token without re-prefill; a
partition outlasting ``partition_timeout`` escalates to the crash path.
A head-of-line request held for more than ``hol_patience`` ticks (its
worst-case page reservation fits nowhere because the pools are
fragmented) **preempts** the newest admitted request on its best
replica — preempted work is requeued-from-prompt behind it, never
dropped, and pays no retry budget.  Every fault-caused
requeue-from-prompt (crash, soft-drain, partition timeout) costs the
victim one retry; a request exhausting ``max_retries`` stops consuming
the fleet and fails terminally with outcome ``failed_retries``.

**Stateful failover** (``migration`` / ``snapshot_every`` /
``rebalance_every``): faults no longer have to cost re-prefill.  A
soft-drained replica's admitted requests are MIGRATED mid-decode via
``engine.export_state`` / ``import_state`` — page contents ship to the
min-ECT compatible peer, the importer's chained-crc32 verification
rejects any corrupted payload before a byte reaches its pool, and the
destination deduplicates shared prefix pages against its content
registry (only non-resident pages transfer; per-replica registry
views are gossiped on heartbeat rounds, which also lets placement
affinity see pages registered after earlier decisions).  Whether to
migrate is a bytes-over-bandwidth decision: payload bytes over the
source+destination ``LinkSpec`` versus re-prefilling prompt + decoded
tokens at the destination's speed plus per-call dispatch overhead —
``migration="auto"`` (default) migrates only when it is cheaper,
``"always"`` skips the cost check, ``"never"`` restores the old
requeue-from-prompt behavior everywhere.  ``rebalance_every`` > 0
additionally migrates the newest-admitted request off the
most-loaded replica whenever its pending-token backlog exceeds
``rebalance_factor``x the least-loaded peer's.  Independently,
``snapshot_every`` > 0 records each admitted request's
``(prefix digests, generated tokens)`` every that-many ticks, so the
CRASH path (where the replica's pages really are gone) restores
tokens-so-far deterministically: the victim re-prefills prompt +
snapshot tokens in one extended admission and re-decodes only what
was generated after the last snapshot.  A migrated request pays no
retry budget and keeps its decode progress; every fallback is the old
requeue-from-prompt path, so nothing new can be dropped — and a
``corrupt``-faulted transfer falls back there with the victim's final
output bitwise-identical to a no-fault run.

Fault tolerance reuses the broker verbatim: every replica's node is
registered ``active``, every standby replica's node ``backup``.  A
heartbeat round can kill a replica mid-decode (standbys are pinged by
the same seeded process — a dead standby is dropped, never drafted); the
broker then drafts the backup whose device speed best matches the dead
one, the router activates the corresponding standby engine, and the dead
replica's in-flight requests (admitted slots AND its internal queue) are
re-queued at the FRONT of the shared queue from their prompts — the
KV/pages died with the replica, so they re-prefill from scratch; nothing
is ever silently dropped.  Drained requests keep their prefix digests
(``drain_requests`` stamps them), so same-prefix victims still co-locate
by affinity and re-share their prefix pages on the survivor.  Requests
on unaffected replicas are untouched (slot isolation keeps their greedy
decode bitwise-identical to a no-failure run).

``run()`` returns a ``FleetResult``: completed requests, terminally
failed requests (every one stamped with a structured ``outcome``), and
a per-request placement/retry/latency trace — partial results are never
raised away.  ``run(strict=True)`` restores the old contract and raises
when anything failed.

Replicas may be heterogeneous in BOTH dimensions: different simulated
devices (speed skews placement toward fast peers) and different
(params, cfg) models (``can_serve`` gates by vocab bound, context
length, and pool size, so a request only routes to replicas whose model
can actually run it).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.core.broker import Broker
from repro.core.perfmodel import (DEVICE_CATALOG, LINK_REGIMES, CompNode,
                                  DeviceSpec, LinkSpec)
from repro.serve.engine import Request, RequestState, ServingEngine
from repro.serve.faults import FaultPlan

DeviceLike = Union[str, DeviceSpec, CompNode]

# terminal request outcomes (Request.outcome)
OUTCOMES = ("ok", "failed_retries", "failed_unservable", "deadline_exceeded")


def sim_node(device: DeviceLike, *,
             link: Optional[LinkSpec] = None, lam: float = 0.75,
             reliability: float = 0.999) -> CompNode:
    """A simulated provider for a replica: catalog name / spec -> CompNode
    (node_id is assigned by the broker at registration)."""
    if isinstance(device, CompNode):
        return device
    spec = DEVICE_CATALOG[device] if isinstance(device, str) else device
    return CompNode(-1, spec, link or LINK_REGIMES["lan_10gbps"], lam=lam,
                    reliability=reliability)


def _flip_payload(state: RequestState) -> None:
    """Apply a ``corrupt`` fault to an in-flight migration payload: flip
    one byte of the first non-empty pool page array (falling back to the
    checksum field for page-free payloads).  The importer's chained-crc32
    verification must reject the transfer — this helper exists so the
    chaos suite can prove it does."""
    for key in sorted(state.pool):
        arr = np.ascontiguousarray(state.pool[key]).copy()
        if arr.nbytes:
            arr.view(np.uint8).reshape(-1)[0] ^= 0xFF
            state.pool[key] = arr
            return
    state.checksum ^= 1


def _flops_per_token(engine: ServingEngine) -> float:
    """Analytic per-token cost of a replica's model: the standard
    2 * params FLOPs/token estimate, read off the replica's own param
    pytree so heterogeneous-model fleets cost each replica correctly."""
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(engine.params))
    return 2.0 * float(n_params)


@dataclass
class Replica:
    """One engine bound to one simulated device, plus its degraded-mode
    state: ``lat_ewma`` (observed tick-latency EWMA, 1.0 = healthy)
    scales its ECT and triggers soft-drain; ``busy_ticks`` counts the
    remaining fleet ticks of a straggling engine tick still in flight;
    ``partition_start`` >= 0 marks it unreachable (engine state
    retained) until ``partitioned_until``."""
    replica_id: int
    engine: ServingEngine
    node: CompNode
    flops_per_token: float
    alive: bool = True
    served: List[int] = field(default_factory=list)   # completed req_ids
    _harvested: int = 0        # prefix of engine.finished already collected
    # -- degraded-mode state (driven by FleetRouter + serve.faults) -----
    lat_ewma: float = 1.0      # tick-latency EWMA; multiplies the ECT
    busy_ticks: int = 0        # straggler: fleet ticks left in current tick
    straggle_factor: float = 1.0
    straggle_until: int = 0    # fleet tick the straggle episode ends
    partition_start: int = -1  # fleet tick the partition began (-1 = none)
    partitioned_until: int = 0
    pressure_until: int = 0    # fleet tick pool_pressure lifts
    corrupt_until: int = 0     # payloads exported before this tick flip
    soft_drained: bool = False  # already drained this degraded episode


@dataclass
class FleetResult:
    """What ``FleetRouter.run()`` produces: ``completed`` requests in
    finish order, terminally ``failed`` requests (each with a structured
    ``Request.outcome``), a per-request ``traces`` dict (req_id ->
    placements / retries / outcome / submitted+finished tick / latency),
    and the total fleet ``ticks`` run.  Iterating or ``len()``-ing the
    result walks the completed requests, so pre-existing
    ``for r in router.run()`` call sites keep working."""
    completed: List[Request]
    failed: List[Request]
    traces: Dict[int, dict]
    ticks: int

    @property
    def ok(self) -> bool:
        return not self.failed

    def outcomes(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for req in self.completed + self.failed:
            counts[req.outcome] = counts.get(req.outcome, 0) + 1
        return counts

    def __iter__(self) -> Iterator[Request]:
        return iter(self.completed)

    def __len__(self) -> int:
        return len(self.completed)

    def __repr__(self) -> str:
        return (f"FleetResult(completed={len(self.completed)}, "
                f"failed={len(self.failed)}, ticks={self.ticks}, "
                f"outcomes={self.outcomes()})")


class FleetRouter:
    """N serving replicas + standby spares behind one FIFO queue, with
    broker membership/failover and degraded-mode fault handling.  See
    the module docstring for semantics.

    ``replicas`` / ``standby``: sequences of ``(engine, device)`` pairs,
    ``device`` a ``DEVICE_CATALOG`` name, a ``DeviceSpec``, or a
    pre-built ``CompNode`` (whose ``reliability`` drives the seeded
    heartbeat failure process).

    ``fault_plan``: an optional ``serve.faults.FaultPlan`` consumed at
    the start of every tick (deterministic fault injection).
    ``drain_factor``: tick-latency EWMA at which a replica is
    soft-drained and stops receiving new work.  ``hol_patience``: held
    ticks before a head-of-line request preempts the newest admitted
    request on its best replica.  ``partition_timeout``: ticks after
    which an unhealed partition escalates to a crash.

    ``migration`` (``"auto"`` | ``"always"`` | ``"never"``): whether
    soft-drain and rebalance ship verified decode state between
    replicas instead of requeueing-from-prompt (``"auto"`` applies the
    bytes-over-bandwidth cost check; ``dispatch_s`` is the per-call
    overhead in its re-prefill estimate).  ``snapshot_every`` > 0
    records each admitted request's (digests, generated) every
    that-many ticks so crashes restore tokens-so-far.
    ``rebalance_every`` > 0 checks every that-many ticks whether the
    most-loaded replica's pending tokens exceed ``rebalance_factor``x
    the least-loaded peer's and migrates its newest-admitted request.

    ``stats`` counts ``placed`` dispatches, ``completed`` requests,
    replica ``failures``, ``requeued`` in-flight requests, backup-pool
    ``replacements``, head-of-line ``held`` ticks, plus the degraded-mode
    counters: ``soft_drains`` / ``preempted`` / ``straggles`` /
    ``partitions`` / ``partition_heals`` / ``partition_escalations`` /
    ``pool_pressure`` / ``injected_crashes`` / ``standby_deaths``, the
    stateful-failover counters: ``migrations`` / ``migration_fallbacks``
    / ``rebalances`` / ``snapshot_restores`` / ``corrupt_faults``, and
    the terminal failure outcomes.  ``placements`` records every
    req_id -> [replica_id, ...] dispatch history (len > 1 = re-queued
    after a fault, or migrated mid-decode).
    """

    def __init__(self, replicas: Sequence[Tuple[ServingEngine, DeviceLike]],
                 standby: Sequence[Tuple[ServingEngine, DeviceLike]] = (),
                 *, seed: int = 0, heartbeat_s: float = 10.0,
                 prefill_call_cost: float = 4.0, tie_eps: float = 0.02,
                 fault_plan: Optional[FaultPlan] = None,
                 drain_factor: float = 3.0, ewma_alpha: float = 0.5,
                 hol_patience: int = 8, partition_timeout: int = 32,
                 migration: str = "auto", snapshot_every: int = 8,
                 rebalance_every: int = 0, rebalance_factor: float = 4.0,
                 dispatch_s: float = 1e-3):
        if not replicas:
            raise ValueError("FleetRouter: at least one replica required")
        if migration not in ("auto", "always", "never"):
            raise ValueError(f"FleetRouter: migration must be 'auto', "
                             f"'always' or 'never', got {migration!r}")
        # admission-aware ECT: each outstanding jitted prefill call costs
        # this many token-equivalents of dispatch overhead on top of its
        # tokens, and each queued request one admission's worth of
        # service latency.  tie_eps is the relative ECT band treated as a
        # near-tie, broken toward prefix affinity then replica id.
        self.prefill_call_cost = prefill_call_cost
        self.tie_eps = tie_eps
        self.fault_plan = fault_plan
        self.drain_factor = drain_factor
        self.ewma_alpha = ewma_alpha
        self.hol_patience = hol_patience
        self.partition_timeout = partition_timeout
        # stateful failover: "auto" migrates when bytes-over-bandwidth
        # beats re-prefill, "always" skips the cost check, "never"
        # restores requeue-from-prompt everywhere.  dispatch_s is the
        # per-jitted-call overhead in the re-prefill cost estimate.
        self.migration = migration
        self.snapshot_every = snapshot_every
        self.rebalance_every = rebalance_every
        self.rebalance_factor = rebalance_factor
        self.dispatch_s = dispatch_s
        self.broker = Broker(seed=seed, heartbeat_s=heartbeat_s)
        self.replicas: List[Replica] = []
        self._standby: Dict[int, Replica] = {}      # node_id -> Replica
        self._by_node: Dict[int, Replica] = {}
        rid = 0
        seen_engines: set = set()
        for pool, pairs in (("active", replicas), ("backup", standby)):
            for engine, device in pairs:
                if id(engine) in seen_engines:
                    raise ValueError(
                        "FleetRouter: the same ServingEngine object was "
                        "passed for two replicas — each replica needs its "
                        "own engine (they hold independent slot caches)")
                seen_engines.add(id(engine))
                node = sim_node(device)
                self.broker.register(node, pool=pool)
                rep = Replica(rid, engine, node, _flops_per_token(engine),
                              alive=(pool == "active"))
                self._by_node[node.node_id] = rep
                if pool == "active":
                    self.replicas.append(rep)
                else:
                    self._standby[node.node_id] = rep
                rid += 1
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.failed: List[Request] = []
        self.placements: Dict[int, List[int]] = {}
        self.tick_count = 0
        self._submit_order: Dict[int, int] = {}     # req_id -> arrival seq
        self._order_seq = 0
        self._submitted_at: Dict[int, int] = {}     # req_id -> submit tick
        self._finished_at: Dict[int, int] = {}      # req_id -> terminal tick
        self._hol_req: Optional[int] = None         # held head req_id
        self._hol_held = 0                          # consecutive held ticks
        self._preempted_ids: set = set()            # ever-preempted req_ids
        # stateful-failover state: periodic (digests, generated) records
        # for the crash path, and the heartbeat-gossiped per-replica
        # content-registry views for affinity + migrate-dedup estimates
        self._snapshots: Dict[int, Tuple[tuple, List[int]]] = {}
        self._registry_view: Dict[int, frozenset] = {}
        self.stats = {"placed": 0, "completed": 0, "failures": 0,
                      "requeued": 0, "replacements": 0, "held": 0,
                      "soft_drains": 0, "preempted": 0, "straggles": 0,
                      "partitions": 0, "partition_heals": 0,
                      "partition_escalations": 0, "pool_pressure": 0,
                      "injected_crashes": 0, "standby_deaths": 0,
                      "migrations": 0, "migration_fallbacks": 0,
                      "rebalances": 0, "rebalance_holds": 0,
                      "snapshot_restores": 0, "corrupt_faults": 0,
                      "failed_retries": 0, "failed_unservable": 0,
                      "deadline_exceeded": 0}

    # -- membership ------------------------------------------------------

    def live_replicas(self) -> List[Replica]:
        return [r for r in self.replicas if r.alive]

    def _reachable(self, rep: Replica) -> bool:
        return rep.alive and rep.partition_start < 0

    def _healthy(self, rep: Replica) -> bool:
        """Eligible for NEW work: reachable and not latency-degraded."""
        return self._reachable(rep) and rep.lat_ewma < self.drain_factor

    def _servable_somewhere(self, req: Request) -> bool:
        pool = self.live_replicas() + list(self._standby.values())
        return any(r.engine.can_serve(req.prompt, req.max_new) for r in pool)

    # -- intake + placement ----------------------------------------------

    def submit(self, req: Request) -> None:
        if not self._servable_somewhere(req):
            raise ValueError(
                f"FleetRouter: no replica (live or standby) can ever serve "
                f"request {req.req_id} (prompt={len(req.prompt)} tokens, "
                f"max_new={req.max_new}) — check vocab/cache_len/pool sizes")
        self._note_order(req)
        self._submitted_at.setdefault(req.req_id, self.tick_count)
        self.queue.append(req)

    def _note_order(self, req: Request) -> None:
        if req.req_id not in self._submit_order:
            self._submit_order[req.req_id] = self._order_seq
            self._order_seq += 1

    def _ect(self, rep: Replica, req: Request) -> float:
        """Eq. 2-style estimated completion time of ``req`` on ``rep``,
        admission-aware: beyond the token count (outstanding work plus
        this request), every jitted chunked-prefill call still ahead —
        the replica's queue, prefix-sharing discounts applied, plus this
        request's own ``ceil(tail/chunk)`` — costs
        ``prefill_call_cost`` token-equivalents of dispatch overhead,
        and each already-queued request one more admission's worth of
        service latency.  The whole estimate is scaled by the replica's
        observed tick-latency EWMA (1.0 when healthy), so stragglers
        price themselves out of placement by exactly how slow they have
        actually been.  Two replicas with equal token backlogs no
        longer tie when one of them has the backlog fragmented across
        many short prompts (more calls, slower wall clock)."""
        eng = rep.engine
        tokens = eng.pending_tokens + len(req.prompt) + req.max_new
        calls = eng.pending_prefill_calls + eng.prefill_calls_for(req.prompt)
        tokens += self.prefill_call_cost * (calls + len(eng.queue))
        return tokens * rep.flops_per_token / rep.node.speed * rep.lat_ewma

    def _affinity(self, rep: Replica, req: Request) -> int:
        """Prefix-affinity score of placing ``req`` on ``rep``: resident
        shared prefix pages the engine could attach RIGHT NOW, or — when
        the pages died with a failed replica — the longest common
        prefix-digest run with a request already queued on ``rep`` (the
        pages will be registered when that request admits, so
        co-locating still converts to sharing), or the leading-digest
        run against the replica's last heartbeat-gossiped registry view
        (pages registered AFTER earlier placement decisions).  Digest
        trails come from ``drain_requests`` for failover requeues and
        are recomputed from the prompt otherwise."""
        eng = rep.engine
        pages = eng.shared_prefix_pages(req.prompt)
        mine = (req.prefix_digests if req.prefix_digests is not None
                else eng.prefix_digests(req.prompt))
        view = self._registry_view.get(rep.replica_id)
        if view:
            run = 0
            for d in mine:
                if d not in view:
                    break
                run += 1
            pages = max(pages, run)
        for other in eng.queue:
            theirs = (other.prefix_digests if other.prefix_digests is not None
                      else eng.prefix_digests(other.prompt))
            common = 0
            for a, b in zip(mine, theirs):
                if a != b:
                    break
                common += 1
            pages = max(pages, common)
        return pages

    def _draft_capable_standby(self, req: Request) -> Optional[Replica]:
        """No LIVE replica can ever serve ``req``: activate the fastest
        standby whose model can (waiting for a failure to draft it would
        hold the queue head forever)."""
        cands = [r for r in self._standby.values()
                 if r.engine.can_serve(req.prompt, req.max_new)]
        if not cands:
            return None
        rep = max(cands, key=lambda r: r.node.speed)
        self.broker.activate_backup(
            rep.node.node_id, f"req {req.req_id} unservable on live fleet")
        self._standby.pop(rep.node.node_id)
        rep.alive = True
        self.replicas.append(rep)
        self.stats["replacements"] += 1
        return rep

    def _dispatch(self) -> None:
        """Place queued requests, FIFO: the head request goes to the
        min-ECT healthy replica (reachable, not latency-degraded) whose
        paged pool can still cover its worst-case reservation; if none
        currently can (but one could later), the head WAITS — later
        requests are not reordered past it — and after ``hol_patience``
        held ticks the newest admitted request on the head's best
        replica is preempted to make room (requeued-from-prompt, never
        dropped).  A head that no live replica could EVER run drafts a
        capable standby from the backup pool, or fails terminally with
        outcome ``failed_unservable`` (never a silent drop, never a
        raise that loses everyone else's results)."""
        while self.queue:
            req = self.queue[0]
            able = [r for r in self.live_replicas()
                    if r.engine.can_serve(req.prompt, req.max_new)]
            if not able:
                drafted = self._draft_capable_standby(req)
                if drafted is None:
                    self.queue.pop(0)
                    self._fail(req, "failed_unservable")
                    continue
                able = [drafted]
            ready = [r for r in able
                     if self._healthy(r)
                     and r.engine.free_pages
                     >= r.engine.blocks_needed(len(req.prompt), req.max_new)]
            if not ready:
                self.stats["held"] += 1
                self._hold_head(req, able)
                if self._hol_held == 0:
                    continue           # preemption made room: retry now
                return
            self._hol_req, self._hol_held = None, 0
            # near-tie break toward prefix affinity: replicas within
            # tie_eps of the best ECT are effectively interchangeable on
            # load, so prefer the one already holding (or about to admit)
            # the request's shared prefix pages; exact ties fall back to
            # the lowest replica id — fully deterministic
            ects = {r.replica_id: self._ect(r, req) for r in ready}
            floor = min(ects.values())
            band = [r for r in ready
                    if ects[r.replica_id] <= floor * (1.0 + self.tie_eps)]
            best = min(band, key=lambda r: (-self._affinity(r, req),
                                            ects[r.replica_id],
                                            r.replica_id))
            self.queue.pop(0)
            best.engine.submit(req)
            self.placements.setdefault(req.req_id, []).append(best.replica_id)
            self.stats["placed"] += 1

    def _hold_head(self, req: Request, able: List[Replica]) -> None:
        """The queue head fits nowhere right now.  Track how long THIS
        head has been held; past ``hol_patience`` consecutive held
        ticks, satisfy its worst-case reservation by preempting the
        newest admitted request(s) on its best healthy replica —
        fragmented pools full of long-running work must not livelock
        the whole queue.  Victims are requeued-from-prompt BEHIND the
        head (their submission order is demoted — preemption
        deliberately reorders in the head's favor) and pay no retry
        budget.  Resets ``_hol_held`` to 0 when preemption made room.

        Anti-thrash: a head that was itself a preemption victim never
        triggers another preemption (it waits for natural drain) — two
        requests too big to coexist would otherwise evict each other
        forever, each eviction resetting the other's decode progress."""
        if self._hol_req != req.req_id:
            self._hol_req, self._hol_held = req.req_id, 0
        self._hol_held += 1
        if self._hol_held <= self.hol_patience:
            return
        if req.req_id in self._preempted_ids:
            return
        cands = [r for r in able if self._healthy(r)]
        if not cands:
            return                      # held on health, not pages: wait
        ects = {r.replica_id: self._ect(r, req) for r in cands}
        best = min(cands, key=lambda r: (ects[r.replica_id], r.replica_id))
        need = best.engine.blocks_needed(len(req.prompt), req.max_new)
        victims: List[Request] = []
        while best.engine.free_pages < need:
            v = best.engine.preempt_newest()
            if v is None:
                break
            victims.append(v)
        if not victims:
            return
        self.stats["preempted"] += len(victims)
        for v in victims:
            # demote behind the head: preemption exists to serve the
            # head, so the victim must not outrank it on requeue
            self._preempted_ids.add(v.req_id)
            self._submit_order[v.req_id] = self._order_seq
            self._order_seq += 1
        self._requeue(victims, count_retry=False)
        if best.engine.free_pages >= need:
            self._hol_held = 0          # room made: dispatch the head now

    # -- failure handling -------------------------------------------------

    def _fail(self, req: Request, outcome: str) -> None:
        """Terminally fail one request with a structured outcome."""
        assert outcome in OUTCOMES and outcome != "ok"
        req.outcome = outcome
        self.failed.append(req)
        self._finished_at[req.req_id] = self.tick_count
        self._snapshots.pop(req.req_id, None)
        self.stats[outcome] += 1

    def _requeue(self, reqs: List[Request], *,
                 count_retry: bool = True) -> None:
        """Put drained/preempted requests back at the front of the shared
        queue in GLOBAL submission order.  Fault-caused requeues
        (``count_retry=True``) cost each victim one retry; a victim past
        its ``max_retries`` budget fails terminally instead of riding
        the fleet forever.  Requests admitted directly via
        ``engine.submit()`` (bypassing the router) join the order book
        here, in arrival-at-drain order."""
        kept: List[Request] = []
        for req in reqs:
            self._note_order(req)
            self._submitted_at.setdefault(req.req_id, self.tick_count)
            if count_retry:
                req.retries += 1
                if req.retries > req.max_retries:
                    self._fail(req, "failed_retries")
                    continue
            kept.append(req)
        self.queue[:0] = kept
        # restore GLOBAL submission order: with several replicas dying in
        # one heartbeat round (or across rounds before redispatch), the
        # per-replica prepends alone would interleave newer requests
        # ahead of older ones
        self.queue.sort(key=lambda r: self._submit_order[r.req_id])
        self.stats["requeued"] += len(kept)

    def _harvest(self, rep: Replica) -> None:
        for req in rep.engine.finished[rep._harvested:]:
            req.outcome = "ok"
            self.finished.append(req)
            self._finished_at[req.req_id] = self.tick_count
            self._snapshots.pop(req.req_id, None)
            rep.served.append(req.req_id)
            self.stats["completed"] += 1
        rep._harvested = len(rep.engine.finished)

    def _on_death(self, node_id: int) -> None:
        rep = self._by_node.get(node_id)
        if rep is None or not rep.alive:
            return
        self._harvest(rep)                 # finished outputs survive
        rep.alive = False
        # the corpse carries no degraded state
        rep.partition_start = -1
        rep.straggle_factor, rep.straggle_until = 1.0, 0
        rep.busy_ticks = 0
        rep.corrupt_until = 0
        self._registry_view.pop(rep.replica_id, None)
        victims = rep.engine.drain_requests()
        for req in victims:
            # the pages died with the replica, but the router's periodic
            # snapshot survives: restore tokens-so-far so the victim
            # re-prefills prompt + snapshot in one extended admission and
            # re-decodes only what came after the last snapshot
            snap = self._snapshots.get(req.req_id)
            if snap:
                req.resume_tokens = list(snap[1])
                if req.prefix_digests is None:
                    req.prefix_digests = list(snap[0])
                self.stats["snapshot_restores"] += 1
        self._requeue(victims)
        self.stats["failures"] += 1
        sub = self.broker.draft_backup(node_id)
        if sub is not None:
            drafted = self._standby.pop(sub.node_id)
            drafted.alive = True
            self.replicas.append(drafted)
            self.stats["replacements"] += 1

    def heartbeat_round(self) -> List[int]:
        """One broker ping-pong round over ALL registered nodes —
        replicas and standbys alike fail with (1 - reliability), seeded.
        A replica failure mid-decode kills it, requeues its in-flight
        requests from their prompts, and drafts a speed-matched standby;
        a standby failure just removes it from the draft pool (a dead
        standby must never be drafted).  Each surviving reachable
        replica's content-registry digest set is gossiped fleet-wide,
        piggybacked on the same round — placement affinity and the
        migrate-dedup byte estimate read this (possibly stale) view, not
        the live engines.  Returns dead node ids."""
        dead = self.broker.heartbeat_round()
        for nid in dead:
            if nid in self._standby:
                self._standby.pop(nid)
                self.stats["standby_deaths"] += 1
            else:
                self._on_death(nid)
        for rep in self.replicas:
            if self._reachable(rep):
                self._registry_view[rep.replica_id] = \
                    rep.engine.registry_digests()
        return dead

    def fail_replica(self, replica_id: int) -> None:
        """Deterministic failure injection (tests/examples): kill one
        replica through the same broker quit -> drain -> requeue ->
        draft path the heartbeat uses.  Killing an already-dead replica
        is a no-op (like ``_on_death``); an id the fleet has never
        activated raises a descriptive ``ValueError``."""
        rep = next((r for r in self.replicas if r.replica_id == replica_id),
                   None)
        if rep is None:
            known = sorted(r.replica_id for r in self.replicas)
            waiting = sorted(r.replica_id for r in self._standby.values())
            raise ValueError(
                f"FleetRouter.fail_replica: unknown replica id "
                f"{replica_id!r} (active/dead replicas: {known}; "
                f"undrafted standbys: {waiting})")
        if not rep.alive:
            return
        self.broker.quit(rep.node.node_id, graceful=False)
        self._on_death(rep.node.node_id)

    # -- fault plane ------------------------------------------------------

    def _kill(self, rep: Replica) -> None:
        self.broker.quit(rep.node.node_id, graceful=False)
        self._on_death(rep.node.node_id)

    def _fault_tick(self) -> None:
        """Expire elapsed fault episodes, then apply this tick's faults
        from the plan.  Runs at the START of every tick so a healed
        partition can receive dispatch the same tick it heals."""
        t = self.tick_count
        for rep in self.replicas:
            if not rep.alive:
                continue
            if rep.partition_start >= 0:
                if t - rep.partition_start >= self.partition_timeout:
                    # the fleet cannot tell a long partition from a
                    # death: escalate through the crash path
                    self.stats["partition_escalations"] += 1
                    self._kill(rep)
                    continue
                if t >= rep.partitioned_until:
                    rep.partition_start = -1
                    self.stats["partition_heals"] += 1
            if rep.straggle_until and t >= rep.straggle_until:
                rep.straggle_factor, rep.straggle_until = 1.0, 0
            if rep.pressure_until and t >= rep.pressure_until:
                rep.engine.set_pool_pressure(0)
                rep.pressure_until = 0
        if self.fault_plan is None:
            return
        for f in self.fault_plan.at(t):
            rep = next((r for r in self.replicas
                        if r.replica_id == f.replica_id and r.alive), None)
            if rep is None:
                continue               # dead, or an undrafted standby
            if f.kind == "crash":
                self.stats["injected_crashes"] += 1
                self._kill(rep)
            elif f.kind == "straggle":
                rep.straggle_factor = max(rep.straggle_factor, f.factor)
                rep.straggle_until = max(rep.straggle_until, t + f.duration)
                self.stats["straggles"] += 1
            elif f.kind == "partition":
                if rep.partition_start < 0:
                    rep.partition_start = t
                rep.partitioned_until = max(rep.partitioned_until,
                                            t + f.duration)
                self.stats["partitions"] += 1
            elif f.kind == "pool_pressure":
                rep.engine.set_pool_pressure(f.pages)
                rep.pressure_until = max(rep.pressure_until, t + f.duration)
                self.stats["pool_pressure"] += 1
            elif f.kind == "corrupt":
                # every migration payload EXPORTED from this replica
                # during the episode arrives byte-flipped; the importer's
                # checksum chain must reject it (see _evacuate)
                rep.corrupt_until = max(rep.corrupt_until, t + f.duration)
                self.stats["corrupt_faults"] += 1

    def _soft_drain(self, rep: Replica) -> None:
        """The replica's observed tick latency crossed ``drain_factor``:
        move its in-flight work to healthier replicas instead of letting
        it crawl — migrating verified decode state where a compatible
        destination exists (zero re-decoded tokens), requeueing
        digest-preserving from the prompt otherwise.  Once per degraded
        episode — the flag rearms when the EWMA recovers below the
        threshold."""
        if rep.soft_drained:
            return
        rep.soft_drained = True
        self.stats["soft_drains"] += 1
        self._evacuate(rep)

    # -- stateful failover (verified KV migration + snapshots) -----------

    def _migration_dest(self, src: Replica, req: Request,
                        state: RequestState) -> Optional[Replica]:
        """Pick where a migrating request should land: healthy peers
        whose engine is migration-compatible (same weights object, same
        architecture and page geometry — ``migration_fingerprint``),
        with a free slot and enough free pages for the request's
        worst-case reservation; min-ECT among them (replica id breaks
        ties).  Under ``migration="auto"`` the winner must also beat
        re-prefill on the bytes-over-bandwidth cost model, else None."""
        cands = []
        for r in self.live_replicas():
            if r is src or not self._healthy(r):
                continue
            eng = r.engine
            if (not eng.paged
                    or eng.migration_fingerprint() != state.fingerprint
                    or not eng.can_serve(req.prompt, req.max_new)
                    or eng.n_active >= eng.slots
                    or eng.free_pages < eng.blocks_needed(len(req.prompt),
                                                          req.max_new)):
                continue
            cands.append(r)
        if not cands:
            return None
        best = min(cands, key=lambda r: (self._ect(r, req), r.replica_id))
        if (self.migration == "always"
                or self._migrate_cheaper(src, best, req, state)):
            return best
        return None

    def _migrate_cheaper(self, src: Replica, dst: Replica, req: Request,
                         state: RequestState) -> bool:
        """The migrate-vs-reprefill decision, in seconds.  Migrating
        ships the payload bytes — minus full prefix pages the
        destination's gossiped registry view says are already resident
        (the importer dedups them, so they never cross the wire) — over
        the source->destination path (latencies add, the slower link's
        inverse bandwidth binds).  Re-prefilling re-runs prompt plus
        every already-decoded token at the destination's analytic speed,
        plus ``dispatch_s`` per jitted call (chunked-prefill calls and
        one decode step per re-decoded token).  Ties migrate: equal wall
        clock with no token recompute is strictly less wasted work."""
        view = self._registry_view.get(dst.replica_id, frozenset())
        resident = sum(1 for d in state.digests if d in view)
        payload = state.payload_bytes - resident * src.engine.page_bytes
        link = LinkSpec(alpha=src.node.link.alpha + dst.node.link.alpha,
                        beta=max(src.node.link.beta, dst.node.link.beta))
        migrate_s = link.time(max(0.0, float(payload)))
        redecode = len(req.generated) + 1          # pending token rides too
        tokens = len(req.prompt) + redecode
        reprefill_s = (tokens * dst.flops_per_token / dst.node.speed
                       + (dst.engine.prefill_calls_for(req.prompt) + redecode)
                       * self.dispatch_s)
        return migrate_s <= reprefill_s

    def _reset_to_prompt(self, req: Request) -> None:
        """A migration fell through after export: mirror what
        ``drain_requests`` does to a victim so the requeue path sees the
        usual re-prefill-from-prompt shape (export already stamped the
        prefix-digest trail)."""
        req.generated = []
        req.pending = -1
        req.done = False

    def _evacuate(self, rep: Replica, *,
                  count_retry: bool = True) -> None:
        """Empty ``rep`` of in-flight work.  Each admitted request is
        exported and imported mid-decode into the best compatible peer —
        a migrated request keeps every decoded token and pays no retry.
        Everything else (no destination, cost model says re-prefill,
        verification rejected the payload, the engine queue) falls back
        to the requeue-from-prompt path, so nothing is ever dropped.  A
        ``corrupt``-faulted source flips a byte in every payload it
        exports; the importer must reject those."""
        fallbacks: List[Request] = []
        if (self.migration != "never" and rep.engine.paged
                and any(r is not rep and self._healthy(r)
                        for r in self.live_replicas())):
            for req in rep.engine.admitted_requests():
                state = rep.engine.export_state(req)
                if state is None:
                    continue            # still queued: drain handles it
                if self.tick_count < rep.corrupt_until:
                    _flip_payload(state)
                dst = self._migration_dest(rep, req, state)
                if dst is not None and dst.engine.import_state(state):
                    self._note_order(req)
                    self._submitted_at.setdefault(req.req_id,
                                                  self.tick_count)
                    self.placements.setdefault(req.req_id, []).append(
                        dst.replica_id)
                    self.stats["migrations"] += 1
                    continue
                self.stats["migration_fallbacks"] += 1
                self._reset_to_prompt(req)
                fallbacks.append(req)
        victims = rep.engine.drain_requests() + fallbacks
        if victims:
            self._requeue(victims, count_retry=count_retry)

    def _rebalance(self) -> None:
        """Load-triggered migration: when the most-loaded healthy
        replica's pending-token backlog exceeds ``rebalance_factor``x
        the least-loaded peer's, its newest-admitted request (the one
        with the most decode work still ahead) migrates off.  If the
        cost model votes against moving — or the transfer is rejected —
        the state is re-imported in place (a no-op rebalance, never a
        lost token); only a doubly-failed import falls back to
        requeue-from-prompt, paying no retry budget."""
        live = [r for r in self.live_replicas()
                if self._healthy(r) and r.engine.paged]
        if len(live) < 2:
            return
        hi = max(live, key=lambda r: (r.engine.pending_tokens,
                                      -r.replica_id))
        lo = min(live, key=lambda r: (r.engine.pending_tokens,
                                      r.replica_id))
        if (hi is lo or hi.engine.n_active == 0
                or hi.engine.pending_tokens
                <= self.rebalance_factor * max(1, lo.engine.pending_tokens)):
            return
        req = hi.engine.admitted_requests()[-1]
        fp = hi.engine.migration_fingerprint()
        if not any(r is not hi
                   and r.engine.migration_fingerprint() == fp
                   and r.engine.n_active < r.engine.slots
                   for r in live):
            return                      # nowhere compatible: stay put
        state = hi.engine.export_state(req)
        if state is None:
            return
        if self.tick_count < hi.corrupt_until:
            _flip_payload(state)
        dst = self._migration_dest(hi, req, state)
        if dst is not None:
            if dst.engine.import_state(state):
                self._note_order(req)
                self._submitted_at.setdefault(req.req_id, self.tick_count)
                self.placements.setdefault(req.req_id, []).append(
                    dst.replica_id)
                self.stats["migrations"] += 1
                self.stats["rebalances"] += 1
                return
            # the destination rejected the payload (corrupt flip): the
            # bytes are suspect, so don't re-import them locally either
            self.stats["migration_fallbacks"] += 1
            self._reset_to_prompt(req)
            self._requeue([req], count_retry=False)
            return
        if hi.engine.import_state(state):
            # moving lost the cost check: re-imported in place (counted
            # separately so imported == migrations + rebalance_holds)
            self.stats["rebalance_holds"] += 1
            return
        self.stats["migration_fallbacks"] += 1
        self._reset_to_prompt(req)
        self._requeue([req], count_retry=False)

    def _snapshot_fleet(self) -> None:
        """Record every reachable admitted request's (prefix digests,
        generated tokens) — the crash path's restore point.  Snapshots
        live at the ROUTER: they must survive the replica whose pages
        they describe."""
        for rep in self.replicas:
            if not self._reachable(rep):
                continue
            for req in rep.engine.admitted_requests():
                if req.generated:
                    self._snapshots[req.req_id] = (
                        tuple(rep.engine.prefix_digests(req.prompt)),
                        list(req.generated))

    # -- the serving loop -------------------------------------------------

    def tick(self) -> int:
        """One fleet iteration: apply/expire faults, dispatch the shared
        queue, tick every reachable replica (a straggler's engine tick
        spans ``straggle_factor`` fleet ticks; a partitioned replica's
        engine is frozen), harvest finished requests, update tick-latency
        EWMAs and soft-drain degraded replicas.  Returns the number of
        active slots across the fleet (in-flight work on partitioned or
        mid-tick replicas still counts — it is not lost)."""
        self._fault_tick()
        if (self.rebalance_every and self.migration != "never"
                and self.tick_count > 0
                and self.tick_count % self.rebalance_every == 0):
            self._rebalance()
        self._dispatch()
        n = 0
        for rep in self.replicas:
            if not rep.alive:
                continue
            if rep.partition_start >= 0:
                n += rep.engine.n_active      # frozen, not lost
                continue
            if rep.busy_ticks > 0:
                rep.busy_ticks -= 1
                n += rep.engine.n_active      # straggling mid-tick
                continue
            cost = (rep.straggle_factor
                    if self.tick_count < rep.straggle_until else 1.0)
            n += rep.engine.tick()
            self._harvest(rep)
            rep.busy_ticks = max(0, int(round(cost)) - 1)
            rep.lat_ewma += self.ewma_alpha * (cost - rep.lat_ewma)
            if rep.lat_ewma >= self.drain_factor:
                self._soft_drain(rep)
            else:
                rep.soft_drained = False
        if (self.snapshot_every
                and self.tick_count % self.snapshot_every == 0):
            self._snapshot_fleet()
        self.tick_count += 1
        return n

    def outstanding(self) -> int:
        """Requests submitted but not yet terminal (shared queue +
        every live replica's queue and slots — including partitioned
        replicas, whose in-flight work is retained)."""
        n = len(self.queue)
        for rep in self.live_replicas():
            n += len(rep.engine.queue) + rep.engine.n_active
        return n

    def _drain_outstanding(self) -> List[Request]:
        """Pull every non-terminal request out of the system (shared
        queue + live replicas), in global submission order."""
        reqs = list(self.queue)
        self.queue = []
        for rep in self.live_replicas():
            reqs.extend(rep.engine.drain_requests())
        for req in reqs:
            self._note_order(req)
        reqs.sort(key=lambda r: self._submit_order[r.req_id])
        return reqs

    def run(self, max_ticks: int = 10_000, heartbeat_every: int = 0,
            *, strict: bool = False) -> FleetResult:
        """Serve until every submitted request reached a TERMINAL
        outcome (or ``max_ticks``).  ``heartbeat_every`` > 0 runs a
        broker heartbeat round every that-many ticks, so seeded failures
        strike mid-decode.  Returns a ``FleetResult`` — completed plus
        terminally failed requests with per-request traces; partial
        results survive fleet death and deadline instead of being raised
        away.  ``strict=True`` restores the old contract: raise if
        anything failed (completed work is still on ``self.finished``)."""
        start = self.tick_count
        for t in range(max_ticks):
            if heartbeat_every and t > 0 and t % heartbeat_every == 0:
                self.heartbeat_round()
            self.tick()
            if not self.outstanding():
                break
        if self.outstanding():
            # max_ticks exhausted with work still in flight: every
            # leftover gets a terminal outcome — nothing silently drops
            outcome = ("deadline_exceeded" if self.live_replicas()
                       else "failed_unservable")
            for req in self._drain_outstanding():
                self._fail(req, outcome)
        traces = {req.req_id: self._trace(req)
                  for req in self.finished + self.failed}
        result = FleetResult(completed=list(self.finished),
                             failed=list(self.failed), traces=traces,
                             ticks=self.tick_count - start)
        if strict and self.failed:
            raise RuntimeError(
                f"FleetRouter: {len(self.failed)} requests failed "
                f"terminally ({result.outcomes()}) after "
                f"{result.ticks} ticks — strict mode refuses partial "
                f"results")
        return result

    def _trace(self, req: Request) -> dict:
        sub = self._submitted_at.get(req.req_id)
        fin = self._finished_at.get(req.req_id)
        return {
            "placements": list(self.placements.get(req.req_id, [])),
            "retries": req.retries,
            "outcome": req.outcome,
            "submitted_tick": sub,
            "finished_tick": fin,
            "latency_ticks": (fin - sub
                              if sub is not None and fin is not None
                              else None),
            "generated": len(req.generated),
        }
