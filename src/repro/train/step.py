"""Train-step builder: loss -> grads -> optimizer, with activation
rematerialization over layer periods, sequence-chunked cross entropy,
optional MTP auxiliary loss (DeepSeek-V3) and microbatch gradient
accumulation (lax.scan) for memory-bound global batches.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import forward, mtp_hidden, unembed
from repro.train.loss import cross_entropy_chunked

Array = jax.Array


def _head_matrix(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, Array], *,
            remat: bool = True, mtp_coef: float = 0.3,
            ce_chunk: int = 512,
            remat_policy: str = "full") -> Tuple[Array, Dict[str, Array]]:
    h, aux, _ = forward(params, cfg, batch, remat=remat, compute_logits=False,
                        remat_policy=remat_policy)
    head = _head_matrix(params, cfg)
    ce, acc = cross_entropy_chunked(h, head, batch["labels"], chunk=ce_chunk)
    loss = ce + aux
    metrics = {"ce": ce, "aux": aux, "acc": acc}
    if cfg.mtp_depth and "mtp" in params and "tokens" in batch:
        B, S = batch["labels"].shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
        # depth-1 MTP: from h_t and token t+1 (== labels_t) predict t+2
        h_mtp, aux_m = mtp_hidden(params, cfg, h, batch["labels"], positions)
        lbl_mtp = jnp.concatenate(
            [batch["labels"][:, 1:],
             jnp.full((B, 1), -1, batch["labels"].dtype)], axis=1)
        ce_m, _ = cross_entropy_chunked(h_mtp, head, lbl_mtp, chunk=ce_chunk)
        loss = loss + mtp_coef * ce_m + aux_m
        metrics["ce_mtp"] = ce_m
    return loss, metrics


def make_train_step(cfg: ModelConfig, optimizer, *, microbatches: int = 1,
                    remat: bool = True, mtp_coef: float = 0.3,
                    ce_chunk: int = 512, donate: bool = True,
                    remat_policy: str = "full") -> Callable:
    """Returns jit-able ``train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)``.  ``microbatches > 1`` accumulates
    gradients over batch slices via lax.scan (memory/compute trade);
    ``remat_policy``: full | dots | dots_no_batch | none."""

    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(p, cfg, b, remat=remat, mtp_coef=mtp_coef,
                             ce_chunk=ce_chunk, remat_policy=remat_policy),
        has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def slice_mb(x):
                B = x.shape[0]
                assert B % microbatches == 0, (B, microbatches)
                return x.reshape((microbatches, B // microbatches) + x.shape[1:])
            mbs = jax.tree.map(slice_mb, batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zero_m = {"ce": 0.0, "aux": 0.0, "acc": 0.0}
            if cfg.mtp_depth and "mtp" in params:
                zero_m["ce_mtp"] = 0.0
            zero_m = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), zero_m)

            def body(carry, mb):
                g_acc, m_acc, l_acc = carry
                (l, m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / microbatches,
                    g_acc, g)
                m_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / microbatches,
                    m_acc, m)
                return (g_acc, m_acc, l_acc + l / microbatches), None

            (grads, metrics, loss), _ = jax.lax.scan(
                body, (zero_g, zero_m, jnp.zeros((), jnp.float32)), mbs)
        new_params, new_opt, opt_metrics = optimizer.update(
            grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, *, ce_chunk: int = 512) -> Callable:
    def eval_step(params, batch):
        _, metrics = loss_fn(params, cfg, batch, remat=False,
                             ce_chunk=ce_chunk)
        return metrics
    return eval_step
