"""Trainer: the end-to-end loop tying data pipeline, train step,
checkpointing and logging together.  Deliberately framework-free — a
~100-line loop a team could actually read."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.data.synthetic import SyntheticLM
from repro.models.config import ModelConfig
from repro.models.transformer import init_params
from repro.optim.adamw import adamw, cosine_lr
from repro.train.step import make_train_step


@dataclass
class TrainConfig:
    steps: int = 100
    lr: float = 3e-4
    warmup: int = 20
    weight_decay: float = 0.01
    microbatches: int = 1
    remat: bool = True
    log_every: int = 10
    ckpt_every: int = 0                 # 0 = disabled
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, loader: SyntheticLM):
        self.cfg = cfg
        self.tcfg = tcfg
        self.loader = loader
        self.optimizer = adamw(
            cosine_lr(tcfg.lr, tcfg.warmup, tcfg.steps),
            weight_decay=tcfg.weight_decay)
        key = jax.random.PRNGKey(tcfg.seed)
        self.params = init_params(key, cfg)
        self.opt_state = self.optimizer.init(self.params)
        self.step_fn = jax.jit(make_train_step(
            cfg, self.optimizer, microbatches=tcfg.microbatches,
            remat=tcfg.remat))
        self.history: List[Dict[str, float]] = []
        self.start_step = 0

    def maybe_restore(self) -> None:
        latest = store.latest_step(self.tcfg.ckpt_dir)
        if latest is not None:
            (self.params, self.opt_state), step = store.restore(
                self.tcfg.ckpt_dir, (self.params, self.opt_state))
            self.start_step = step

    def fit(self, log: Callable[[str], None] = print) -> List[Dict[str, float]]:
        t0 = time.time()
        for step in range(self.start_step, self.tcfg.steps):
            batch = self.loader.batch(step)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            if step % self.tcfg.log_every == 0 or step == self.tcfg.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["wall_s"] = time.time() - t0
                self.history.append(m)
                log(f"step {step:5d}  loss {m['loss']:.4f}  ce {m['ce']:.4f}  "
                    f"acc {m['acc']:.3f}  gnorm {m['grad_norm']:.2f}  "
                    f"lr {m['lr']:.2e}  [{m['wall_s']:.1f}s]")
            if self.tcfg.ckpt_every and (step + 1) % self.tcfg.ckpt_every == 0:
                store.save(self.tcfg.ckpt_dir, step + 1,
                           (self.params, self.opt_state))
        return self.history
