"""Loss functions.  Cross entropy is computed *chunked over the sequence*
with a rematerialized body so (B, S, vocab) float32 logits are never alive
at once — at llama3-405b train_4k the full logit tensor would be 2.1 TB.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

IGNORE = -1


def _ce_of_logits(logits: Array, labels: Array, z_coef: float):
    """logits (N,V) f32, labels (N,). Returns (sum_nll, sum_z, n_valid)."""
    valid = labels != IGNORE
    lbl = jnp.where(valid, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, lbl[:, None], axis=-1)[:, 0]
    nll = (lse - picked) * valid
    z = jnp.square(lse) * valid
    return nll.sum(), z_coef * z.sum(), valid.sum()


def cross_entropy_chunked(h: Array, head: Array, labels: Array, *,
                          chunk: int = 512, z_coef: float = 0.0
                          ) -> Tuple[Array, Array]:
    """h: (B,S,d); head: (d,V); labels: (B,S) with IGNORE masking.
    Returns (mean_loss, accuracy-proxy: mean correct@1)."""
    B, S, d = h.shape
    N = B * S
    hf = h.reshape(N, d)
    lf = labels.reshape(N)
    c = min(chunk * max(1, B), N)
    n_chunks = -(-N // c)
    pad = n_chunks * c - N
    if pad:
        hf = jnp.pad(hf, ((0, pad), (0, 0)))
        lf = jnp.pad(lf, (0, pad), constant_values=IGNORE)
    hf = hf.reshape(n_chunks, c, d)
    lf = lf.reshape(n_chunks, c)

    @jax.checkpoint
    def body(carry, xs):
        s_nll, s_z, s_n, s_hit = carry
        hc, lc = xs
        logits = (hc @ head.astype(hc.dtype)).astype(jnp.float32)
        nll, z, n = _ce_of_logits(logits, lc, z_coef)
        hit = jnp.sum((jnp.argmax(logits, -1) == lc) & (lc != IGNORE))
        return (s_nll + nll, s_z + z, s_n + n, s_hit + hit), None

    zero = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
    (nll, z, n, hit), _ = jax.lax.scan(body, zero, (hf, lf))
    n = jnp.maximum(n, 1)
    return (nll + z) / n, hit / n
