"""Gemma 3 12B — dense decoder with 5:1 local:global attention, 128k
context.  [hf:google/gemma-3-1b-pt family card, scaled to 12B]
"""
from repro.models.config import ATTN, DENSE, SWA, LayerSpec, ModelConfig, reduced

# period of 6: 5 sliding-window layers then 1 global layer
_PERIOD = tuple(LayerSpec(mixer=SWA if i < 5 else ATTN, ffn=DENSE)
                for i in range(6))

CONFIG = ModelConfig(
    name="gemma3-12b",
    arch_type="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,              # gemma3 decouples head_dim from d_model
    d_ff=15360,
    vocab_size=262144,
    period=_PERIOD,
    sliding_window=1024,
    qk_norm=True,
    rope_theta=1_000_000.0,    # global-layer base; local layers use the same
                               # base here (single-theta simplification)
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt (family), gemma3 report",
)

SMOKE = reduced(
    CONFIG,
    period=(LayerSpec(mixer=SWA), LayerSpec(mixer=ATTN)),
    n_layers=2,
)
