"""LLaVA-NeXT (Mistral-7B backbone) — VLM.  The anyres vision tower
(CLIP ViT-L/336 + 2-layer MLP projector) is STUBBED per the assignment:
``input_specs`` supplies precomputed patch embeddings (ext_embed_dim=1024,
the projector input width); this config is the language backbone that
consumes them interleaved with text tokens.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    arch_type="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1_000_000.0,
    ext_embed_dim=1024,        # CLIP ViT-L penultimate features (stub input)
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

SMOKE = reduced(CONFIG, n_layers=2, period=CONFIG.period * 2)
