"""Llama-3.1 405B — dense decoder, GQA kv=8, 128k vocab.
[arXiv:2407.21783]
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="llama3-405b",
    arch_type="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500_000.0,
    source="arXiv:2407.21783 (Llama 3)",
)

SMOKE = reduced(CONFIG, n_layers=2, period=CONFIG.period * 2)
