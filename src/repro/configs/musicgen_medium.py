"""MusicGen-medium — decoder-only transformer over EnCodec tokens.
The EnCodec conv codec is STUBBED per the assignment: ``input_specs``
supplies precomputed frame embeddings (ext_embed_dim=128, the EnCodec
latent width); this config is the acoustic LM backbone.
[arXiv:2306.05284]
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,             # MHA
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,           # EnCodec codebook size
    ext_embed_dim=128,         # EnCodec latent dim (stub input)
    source="arXiv:2306.05284 (MusicGen)",
)

SMOKE = reduced(CONFIG, n_layers=2, period=CONFIG.period * 2,
                n_kv_heads=4, n_heads=4)
