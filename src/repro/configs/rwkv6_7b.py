"""RWKV-6 "Finch" 7B — attention-free RNN with data-dependent decay.
[arXiv:2404.05892]
"""
from repro.models.config import DENSE, RWKV, LayerSpec, ModelConfig, reduced

CONFIG = ModelConfig(
    name="rwkv6-7b",
    arch_type="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=0,                 # attention-free
    n_kv_heads=0,
    d_ff=14336,
    vocab_size=65536,
    period=(LayerSpec(mixer=RWKV, ffn=DENSE),),
    rwkv_head_dim=64,
    rwkv_decay_lora=64,
    source="arXiv:2404.05892 (RWKV-6 Finch)",
)

SMOKE = reduced(CONFIG, n_layers=2, period=CONFIG.period * 2)
