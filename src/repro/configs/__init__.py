"""Architecture registry: the 10 assigned architectures plus the paper's
own evaluation models (BERT-Large, GPT-3 24L).

Each ``<arch>.py`` module exports ``CONFIG`` (exact assigned spec, source
cited) and ``SMOKE`` (reduced same-family variant: <=2 periods,
d_model<=512, <=4 experts, runnable on CPU).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, Tuple

from repro.models.config import ModelConfig

_ARCH_MODULES = {
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "gemma3-12b": "gemma3_12b",
    "qwen1.5-32b": "qwen1_5_32b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "musicgen-medium": "musicgen_medium",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "rwkv6-7b": "rwkv6_7b",
    "qwen3-8b": "qwen3_8b",
    "llama3-405b": "llama3_405b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    # the paper's own estimation subjects (§4, Figs. 4-6)
    "bert-large": "bert_large",
    "gpt3-24l": "gpt3_24l",
}

ASSIGNED_ARCHS: Tuple[str, ...] = tuple(list(_ARCH_MODULES)[:10])
ALL_ARCHS: Tuple[str, ...] = tuple(_ARCH_MODULES)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.SMOKE


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) is part of the baseline matrix.
    long_500k needs a sub-quadratic decode path (SSM / hybrid / SWA) —
    pure full-attention archs are skipped per the assignment carve-out."""
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        return False, "pure full-attention arch: no sub-quadratic long-context path"
    return True, ""


def baseline_pairs():
    """All (arch, shape) pairs in the baseline matrix, plus skip notes."""
    pairs, skips = [], []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            (pairs if ok else skips).append((arch, shape.name) if ok
                                            else (arch, shape.name, why))
    return pairs, skips
