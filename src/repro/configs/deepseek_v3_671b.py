"""DeepSeek-V3 671B — MLA + MoE (1 shared + 256 routed, top-8) + MTP.

61 layers: first 3 dense FFN, remaining 58 MoE.  Multi-head Latent
Attention with q_lora 1536 / kv_lora 512 / nope 128 / rope 64 / v 128;
one multi-token-prediction module.  [arXiv:2412.19437]
"""
from repro.models.config import ATTN, DENSE, MOE, LayerSpec, ModelConfig, reduced

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,            # MLA: all heads share one latent cache
    head_dim=128,
    d_ff=18432,                # dense-layer FFN width
    vocab_size=129280,
    prefix_layers=(LayerSpec(ffn=DENSE),) * 3,
    period=(LayerSpec(mixer=ATTN, ffn=MOE),),
    n_experts=256,
    top_k=8,
    d_expert=2048,
    n_shared_experts=1,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    mtp_depth=1,
    source="arXiv:2412.19437 (DeepSeek-V3)",
)

SMOKE = reduced(CONFIG, n_layers=3, prefix_layers=CONFIG.prefix_layers[:1],
                period=CONFIG.period * 2, n_heads=4, n_kv_heads=4)
