"""GPT-3-style model with 24 layers and hidden size 4096 — the paper's
Fig. 6 estimation subject.  [FusionAI §4 Fig.6]
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="gpt3-24l",
    arch_type="dense",
    n_layers=24,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=16384,
    vocab_size=50257,
    source="FusionAI §4 Fig.6 subject (GPT-3 24L/4096)",
)

SMOKE = reduced(CONFIG, n_layers=2, period=CONFIG.period * 2,
                n_kv_heads=4, n_heads=4)
