"""BERT-Large — the paper's own Fig. 4/5 estimation subject (24 layers,
d=1024, 16 heads, ff=4096).  Used causally here (the FusionAI DAG and perf
model are attention-direction agnostic).  [Devlin et al. 2018; FusionAI §4]
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="bert-large",
    arch_type="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=30522,
    source="FusionAI §4 Fig.4/5 subject (BERT-Large)",
)

SMOKE = reduced(CONFIG, n_layers=2, period=CONFIG.period * 2,
                n_kv_heads=4, n_heads=4)
