"""Qwen3-235B-A22B — MoE decoder: 128 experts, top-8, GQA kv=4, qk-norm.
[hf:Qwen/Qwen3-30B-A3B family card, scaled to 235B-A22B]
"""
from repro.models.config import MOE, LayerSpec, ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,                 # per-expert FFN width
    vocab_size=151936,
    period=(LayerSpec(ffn=MOE),),
    n_experts=128,
    top_k=8,
    d_expert=1536,
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B (family)",
)

SMOKE = reduced(CONFIG, n_layers=2, period=CONFIG.period * 2)
