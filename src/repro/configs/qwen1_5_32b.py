"""Qwen1.5-32B — dense decoder with QKV bias.
[hf:Qwen/Qwen1.5-0.5B family card, scaled to 32B]
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    arch_type="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,            # MHA-style GQA with kv=40
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    attn_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-0.5B (family)",
)

SMOKE = reduced(CONFIG, n_layers=2,
                period=CONFIG.period * 2,
                n_kv_heads=4, n_heads=4)  # keep MHA (kv == q heads)
