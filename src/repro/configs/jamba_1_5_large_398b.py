"""Jamba-1.5-Large (398B total / 94B active) — hybrid Mamba+attention MoE.

72 layers in 9 blocks of 8 (1 attention + 7 Mamba per block, 1:7
interleave), MoE (16 experts, top-2) on every second layer.
[arXiv:2403.19887 / arXiv:2408.12570]
"""
from repro.models.config import ATTN, DENSE, MAMBA, MOE, LayerSpec, ModelConfig, reduced

# Jamba block of 8: attention at index 0; MoE on odd layers (every 2nd).
_PERIOD = tuple(
    LayerSpec(mixer=ATTN if i == 0 else MAMBA, ffn=MOE if i % 2 == 1 else DENSE)
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    period=_PERIOD,
    n_experts=16,
    top_k=2,
    d_expert=24576,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    source="arXiv:2403.19887 (Jamba), 2408.12570 (Jamba-1.5)",
)

# Reduced same-family smoke: keep the 1 attn : 3 mamba interleave + MoE on
# every 2nd layer, tiny dims.
SMOKE = reduced(
    CONFIG,
    period=tuple(LayerSpec(mixer=ATTN if i == 0 else MAMBA,
                           ffn=MOE if i % 2 == 1 else DENSE) for i in range(4)),
    n_layers=4,
)
