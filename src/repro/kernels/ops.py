"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the kernel
body runs as traced JAX ops); on a real TPU set ``interpret=False`` (or
env REPRO_PALLAS_COMPILE=1) to compile through Mosaic.  Model code calls
these wrappers, never ``pallas_call`` directly.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa
from repro.kernels import quantize as _q
from repro.kernels import ssm_scan as _s

_INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


@partial(jax.jit, static_argnames=("causal", "window", "scale",
                                   "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None,
                    block_q: int = _fa.DEFAULT_BLOCK_Q,
                    block_k: int = _fa.DEFAULT_BLOCK_K):
    """q: (B,Hq,S,D); k,v: (B,Hkv,T,D) -> (B,Hq,S,D)."""
    return _fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   scale=scale, block_q=block_q,
                                   block_k=block_k, interpret=_INTERPRET)


@partial(jax.jit, static_argnames=("scale", "window", "softcap"))
def paged_attention(q, k, v, pos, table, q_pos, *,
                    scale: float | None = None, window: int = 0,
                    softcap: float = 0.0, q_extra=None, k_extra=None):
    """Paged single-token decode attention over a block-table pool.

    q: (B,1,Hq,D); k/v: (N,page,Hkv,D*) pools; pos: (N,page); table:
    (B,n_cols); q_pos: (B,1) -> (B,1,Hq,Dv).  The block table is
    scalar-prefetched and drives the page DMA — no gathered K/V copy
    lands in HBM (see ``repro.kernels.paged_attention``)."""
    return _pa.paged_attention_fwd(q, k, v, pos, table, q_pos, scale=scale,
                                   window=window, softcap=softcap,
                                   q_extra=q_extra, k_extra=k_extra,
                                   interpret=_INTERPRET)


@partial(jax.jit, static_argnames=("block",))
def int8_quantize(x, *, block: int = _q.DEFAULT_BLOCK):
    return _q.int8_quantize(x, block=block, interpret=_INTERPRET)


@partial(jax.jit, static_argnames=("shape", "dtype"))
def int8_dequantize(q, scales, shape, dtype=jnp.float32):
    return _q.int8_dequantize(q, scales, shape, dtype, interpret=_INTERPRET)


@partial(jax.jit, static_argnames=("chunk", "di_block"))
def mamba_scan(x, dt, b, c, a, *, chunk: int = _s.DEFAULT_CHUNK,
               di_block: int = _s.DEFAULT_DI_BLOCK):
    """Selective scan: x,dt (B,S,di); b,c (B,S,ds); a (di,ds)."""
    return _s.mamba_scan(x, dt, b, c, a, chunk=chunk, di_block=di_block,
                         interpret=_INTERPRET)


@partial(jax.jit, static_argnames=("chunk",))
def rwkv_scan(r, k, v, w, u, *, chunk: int = _s.DEFAULT_CHUNK):
    """RWKV6 wkv: r,k,v,w (B,S,H,hd); u (H,hd)."""
    return _s.rwkv_scan(r, k, v, w, u, chunk=chunk, interpret=_INTERPRET)
