"""Pallas TPU flash attention (forward): online-softmax over KV blocks.

TPU-native tiling: grid (batch*q_heads, q_blocks, kv_blocks) with the KV
axis innermost ("arbitrary" = sequential), so the (m, l, acc) running
statistics live in VMEM scratch across KV steps.  Block shapes are
MXU-aligned (q/k blocks of 128, head dim padded to a multiple of 128 by
the wrapper).  GQA is handled by the kv index_map (no KV replication in
HBM).  Supports causal and sliding-window masking.

This is the TARGET kernel (pl.pallas_call + BlockSpec); correctness is
validated in interpret mode against ``ref.attention_ref``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import CompilerParams

NEG_INF = -1e30

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      scale: float, causal: bool, window: int,
                      bq: int, bk: int, n_kv_blocks: int, t_real: int):
    """One (head, q-block, kv-block) grid step."""
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # (bq, D)
    k = k_ref[0].astype(jnp.float32)                    # (bk, D)
    v = v_ref[0].astype(jnp.float32)                    # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < t_real                               # kv padding
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        scale: float | None = None,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = True) -> jax.Array:
    """q: (B, Hq, S, D); k, v: (B, Hkv, T, D).  Returns (B, Hq, S, D).

    Pads S/T/D to block multiples; D padding is free for the softmax
    (zero dot contributions) and sliced off on output.
    """
    B, Hq, S, D = q.shape
    _, Hkv, T, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5

    bq = min(block_q, max(8, 1 << (S - 1).bit_length() if S < block_q else block_q))
    bk = min(block_k, max(8, 1 << (T - 1).bit_length() if T < block_k else block_k))
    d_pad = -D % 128 if D % 128 else 0
    s_pad = -S % bq
    t_pad = -T % bk

    qp = jnp.pad(q, ((0, 0), (0, 0), (0, s_pad), (0, d_pad)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, t_pad), (0, d_pad)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, t_pad), (0, d_pad)))
    Dp = D + d_pad
    Sp, Tp = S + s_pad, T + t_pad
    qp = qp.reshape(B * Hq, Sp, Dp)
    kp = kp.reshape(B * Hkv, Tp, Dp)
    vp = vp.reshape(B * Hkv, Tp, Dp)

    n_q_blocks = Sp // bq
    n_kv_blocks = Tp // bk

    def kv_index(bh, iq, ik):
        b, h = bh // Hq, bh % Hq
        return (b * Hkv + h // G, ik, 0)

    kernel = functools.partial(
        _flash_fwd_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kv_blocks=n_kv_blocks, t_real=T)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, n_q_blocks, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, bq, Dp), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, Dp), kv_index),
            pl.BlockSpec((1, bk, Dp), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, Dp), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sp, Dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dp), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out.reshape(B, Hq, Sp, Dp)[:, :, :S, :D]
