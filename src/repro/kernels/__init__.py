# Pallas TPU kernels: the serving engine's paged-decode attention fast
# path (paged_attention.py, dispatched via layers.attention(...,
# use_kernel=True)) plus the seed flash-attention / SSM-scan / int8
# kernels.  Public surface = the jit'd wrappers in ops.py; parity
# oracles in ref.py / the model's blocked attention.  See README.md for
# the grid/BlockSpec layouts and the interpret-mode CPU story.
import jax.experimental.pallas.tpu as _pltpu

# jax renamed TPUCompilerParams -> CompilerParams; one shim here so the
# kernels import (and run in interpret mode) on either side of the rename
CompilerParams = (getattr(_pltpu, "CompilerParams", None)
                  or _pltpu.TPUCompilerParams)
