"""Pallas TPU int8 block quantization — the §2.3 communication-compression
hot path (quantize gradients/activations before crossing slow links).

Per-row absmax scaling over a (rows_tile, block) VMEM tile; encode emits
int8 codes + f32 scales, decode reverses.  Elementwise + row-reduce only,
so tiles just need VREG-friendly lane widths (block multiple of 128).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

DEFAULT_BLOCK = 256
DEFAULT_ROWS = 64


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                  # (rows, block)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = absmax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]).astype(
        x_ref.dtype)


def int8_quantize(x: jax.Array, *, block: int = DEFAULT_BLOCK,
                  rows_tile: int = DEFAULT_ROWS, interpret: bool = True):
    """x: any shape -> (codes int8 (n_rows, block), scales f32 (n_rows, 1)).
    Rows are contiguous ``block``-element groups of the flattened input."""
    flat = x.reshape(-1)
    n = flat.size
    pad = -n % block
    flat = jnp.pad(flat, (0, pad))
    rows = flat.size // block
    xb = flat.reshape(rows, block)
    row_pad = -rows % rows_tile
    xb = jnp.pad(xb, ((0, row_pad), (0, 0)))
    n_tiles = xb.shape[0] // rows_tile

    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((rows_tile, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows_tile, block), lambda i: (i, 0)),
                   pl.BlockSpec((rows_tile, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct(xb.shape, jnp.int8),
                   jax.ShapeDtypeStruct((xb.shape[0], 1), jnp.float32)],
        interpret=interpret,
    )(xb)
    return q[:rows], s[:rows]


def int8_dequantize(q: jax.Array, scales: jax.Array, shape, dtype=jnp.float32,
                    *, rows_tile: int = DEFAULT_ROWS, interpret: bool = True):
    rows, block = q.shape
    row_pad = -rows % rows_tile
    qb = jnp.pad(q, ((0, row_pad), (0, 0)))
    sb = jnp.pad(scales, ((0, row_pad), (0, 0)))
    n_tiles = qb.shape[0] // rows_tile

    x = pl.pallas_call(
        _dequant_kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((rows_tile, block), lambda i: (i, 0)),
                  pl.BlockSpec((rows_tile, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows_tile, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(qb.shape, dtype),
        interpret=interpret,
    )(qb, sb)
    n = math.prod(shape)
    return x[:rows].reshape(-1)[:n].reshape(shape)
