"""Pallas paged-decode attention: block-table-aware online-softmax.

The serving engine's decode hot path attends one query token per slot
against a paged KV pool (PR 2).  The scan-path reference in
``repro.models.layers.attention`` pays for every online-softmax step
with a ``pool[safe_table]`` gather that materializes a (B, C, Hkv, D)
K/V copy in HBM before the math starts.  This kernel fuses the
block-table walk into the attention loop instead:

* grid ``(slot * kv_head, page_column)`` with the page axis innermost
  ("arbitrary" = sequential) so the (m, l, acc) online-softmax running
  statistics live in VMEM scratch across pages;
* the per-slot block table (and the query positions) are
  **scalar-prefetched** (``pltpu.PrefetchScalarGridSpec``) and drive the
  K/V/pos ``BlockSpec`` index_maps — each grid step DMAs exactly one
  pool page HBM -> VMEM, so no gathered K/V copy ever lands in HBM;
* ``-1`` table columns (unallocated pages) are clamped to block 0 for
  the DMA and force-masked in the kernel body, making them
  exactly-neutral in the same online-softmax as the scan path — the
  masking/accumulation math is identical, preserving the paged engine's
  parity story;
* GQA via the index_map (each kv head's pages are read once and shared
  by its G query heads — no KV replication in HBM);
* SWA by handing the kernel only the ring columns of the table
  (``swa_ring_blocks``) plus the window mask — ring pages wrap exactly
  as in the scan path;
* MLA absorbed decode via the optional second score contraction
  (``q_extra @ k_extra^T``, the rope term): k IS the latent pool, v the
  same pool, k_extra the rope pool — all three walked page-wise.

The call carries an analytic ``pl.CostEstimate`` built by
``paged_attention_cost`` — the kernel's exact DMA schedule (each page
read once per kv head, q/out once per (slot, head), no intermediate
copies), which is what ``compiled.cost_analysis()`` reports for the
fused op on a Mosaic compile and what ``benchmarks/micro.py::
paged_kernel_bench`` compares against the gather path's XLA-costed
bytes.  (Interpret mode emulates block DMA with loop-carried copies, so
its own XLA byte count measures the interpreter, not the kernel.)

Compiled mode pads head dims to lane multiples (128) — exact, zero pad
contributes nothing to dots or softmax — and wants ``page_size`` a
sublane multiple (8 for f32 pools, 16 for bf16).  ``interpret=True``
(the default on this CPU container, see ``repro.kernels.ops``) runs the
same body as traced JAX ops, which is also what the production dry-run
lowers on the host-device mesh.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import CompilerParams

NEG_INF = -1e30


def _spec_plan(B: int, Hq: int, Hkv: int, page: int, n_cols: int,
               D: int, Dv: int, De: int, itemsize: int):
    """The kernel's block layout AND its DMA schedule from one source.

    Returns (in_specs, out_spec, bytes_accessed) where each entry of the
    plan is one ``BlockSpec`` plus the number of distinct fetches the
    grid performs for it: kv/pos blocks are re-indexed every page column
    (``B*Hkv*n_cols`` fetches), q/out blocks depend only on the parallel
    axis (``B*Hkv`` fetches — they revisit across the sequential page
    axis, so Mosaic keeps them in VMEM).  ``bytes_accessed`` is the sum
    over the same plan (+ the scalar-prefetch operands), so any change
    to the block shapes or index_maps changes the advertised cost with
    it — this is the ``pl.CostEstimate`` a Mosaic compile reports
    through ``cost_analysis()``."""
    G = Hq // Hkv

    def head_index(bh, ic, tab, qp):
        return (bh // Hkv, bh % Hkv, 0, 0)

    def kv_index(bh, ic, tab, qp):
        # the scalar-prefetched table drives the page DMA: one pool page
        # per grid step, straight from HBM (unallocated -> block 0, the
        # body force-masks it)
        return (jnp.maximum(tab[bh // Hkv, ic], 0), 0, bh % Hkv, 0)

    def pos_index(bh, ic, tab, qp):
        return (jnp.maximum(tab[bh // Hkv, ic], 0), 0)

    per_head = B * Hkv                      # fetched once per (slot, head)
    per_page = B * Hkv * n_cols             # re-fetched every page column
    plan = [  # (BlockSpec, fetches, itemsize)
        (pl.BlockSpec((1, 1, G, D), head_index), per_head, itemsize),
        (pl.BlockSpec((1, page, 1, D), kv_index), per_page, itemsize),
        (pl.BlockSpec((1, page, 1, Dv), kv_index), per_page, itemsize),
        (pl.BlockSpec((1, page), pos_index), per_page, 4),
    ]
    if De:
        plan += [(pl.BlockSpec((1, 1, G, De), head_index), per_head,
                  itemsize),
                 (pl.BlockSpec((1, page, 1, De), kv_index), per_page,
                  itemsize)]
    out_spec = pl.BlockSpec((1, 1, G, Dv), head_index)
    byt = B * n_cols * 4 + B * 4            # scalar-prefetch table + q_pos
    for spec, fetches, isz in plan + [(out_spec, per_head, itemsize)]:
        blk = 1
        for s in spec.block_shape:
            blk *= s
        byt += blk * fetches * isz
    return [s for s, _, _ in plan], out_spec, byt


def paged_attention_cost(q, k, v, table, q_extra=None,
                         interpret: bool = True) -> pl.CostEstimate:
    """Analytic cost of one paged-decode call — the DMA schedule the
    grid actually executes, derived from the SAME spec plan the kernel
    is built from (``_spec_plan``): every table column's K/V (+rope)
    page read once per kv head, q and the output touched once per
    (slot, kv head), scalar table/q_pos in SMEM.  No gathered copy, so
    no other HBM term exists.  Pass the same ``interpret`` flag as the
    call being costed: compiled (Mosaic) mode lane-pads head dims to
    128, so its blocks — and therefore its DMA bytes — are wider than
    interpret mode's."""
    B, _, Hq, D = q.shape
    N, page, Hkv, Dv = v.shape
    n_cols = table.shape[1]
    De = 0 if q_extra is None else q_extra.shape[-1]
    if not interpret:                      # mirror the fwd lane padding
        D += -D % 128
        Dv += -Dv % 128
        De += -De % 128 if De else 0
    _, _, byt = _spec_plan(B, Hq, Hkv, page, n_cols, D, Dv, De,
                           q.dtype.itemsize)
    T = n_cols * page
    flops = 2 * B * Hq * T * (D + Dv + De)
    return pl.CostEstimate(flops=flops, transcendentals=B * Hq * T,
                           bytes_accessed=byt)


def _paged_decode_kernel(tab_ref, qpos_ref, q_ref, k_ref, v_ref, pos_ref,
                         *rest, hkv: int, scale: float, window: int,
                         softcap: float, n_cols: int, has_extra: bool):
    """One (slot*kv_head, page_column) grid step.

    Blocks: q (1, 1, G, D); k (1, page, 1, D); v (1, page, 1, Dv);
    pos (1, page); [qe (1, 1, G, De); ke (1, page, 1, De)];
    out (1, 1, G, Dv); scratch m/l (G, 1), acc (G, Dv) — all f32.
    """
    if has_extra:
        qe_ref, ke_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    bh = pl.program_id(0)
    ic = pl.program_id(1)
    b = bh // hkv

    @pl.when(ic == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    blk = tab_ref[b, ic]                                 # -1 = unallocated
    q_pos = qpos_ref[b]                                  # -1 = idle slot
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (page, D)
    v = v_ref[0, :, 0].astype(jnp.float32)               # (page, Dv)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, page)
    if has_extra:
        qe = qe_ref[0, 0].astype(jnp.float32) * scale    # (G, De)
        ke = ke_ref[0, :, 0].astype(jnp.float32)         # (page, De)
        s = s + jax.lax.dot_general(qe, ke, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    pj = pos_ref[0]                                      # (page,) int32
    ok = (blk >= 0) & (pj >= 0) & (pj <= q_pos)          # causal + validity
    if window > 0:
        ok &= pj > q_pos - window
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    # fully-masked pages: exp(NEG_INF - NEG_INF) = 1 — zero it like the
    # scan path so unallocated pages carry exactly-zero probability mass
    p = jnp.where(ok[None, :], jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ic == n_cols - 1)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def paged_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        pos: jax.Array, table: jax.Array, q_pos: jax.Array,
                        *, scale: float | None = None, window: int = 0,
                        softcap: float = 0.0,
                        q_extra: jax.Array | None = None,
                        k_extra: jax.Array | None = None,
                        interpret: bool = True) -> jax.Array:
    """Paged single-token decode attention.

    q: (B, 1, Hq, D); k: (N, page, Hkv, D); v: (N, page, Hkv, Dv);
    pos: (N, page) int32 (entries < 0 = unwritten); table: (B, n_cols)
    int32 block table (entries < 0 = unallocated); q_pos: (B, 1) int32
    (< 0 = idle slot, whose output is exactly 0 like the scan path).
    q_extra: (B, 1, Hq, De) / k_extra: (N, page, Hkv, De) add a second
    score contraction before the softmax (MLA rope term).

    Returns (B, 1, Hq, Dv) in q.dtype; accumulation in float32.
    """
    B, S, Hq, D = q.shape
    assert S == 1, "paged decode kernel is single-token (S == 1) only"
    N, page, Hkv, Dv = v.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    n_cols = table.shape[1]
    if scale is None:
        scale = D ** -0.5
    has_extra = q_extra is not None
    # the advertised cost comes from the same spec plan the blocks are
    # built from below (one source of truth; see paged_attention_cost)
    cost = paged_attention_cost(q, k, v, table, q_extra,
                                interpret=interpret)

    # lane padding for MXU/VPU tiles when compiling through Mosaic —
    # exact (zero columns contribute nothing to either dot), skipped in
    # interpret mode where it would only waste host flops
    d_pad = 0 if interpret else -D % 128
    dv_pad = 0 if interpret else -Dv % 128
    qh = q.reshape(B, Hkv, G, D)
    if d_pad:
        qh = jnp.pad(qh, ((0, 0), (0, 0), (0, 0), (0, d_pad)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, d_pad)))
    if dv_pad:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dv_pad)))
    Dp, Dvp = D + d_pad, Dv + dv_pad

    operands = [qh, k, v, pos]
    Dep = 0
    if has_extra:
        De = q_extra.shape[-1]
        de_pad = 0 if interpret else -De % 128
        qe = q_extra.reshape(B, Hkv, G, De)
        ke = k_extra
        if de_pad:
            qe = jnp.pad(qe, ((0, 0), (0, 0), (0, 0), (0, de_pad)))
            ke = jnp.pad(ke, ((0, 0), (0, 0), (0, 0), (0, de_pad)))
        Dep = De + de_pad
        operands += [qe, ke]

    in_specs, out_spec, _ = _spec_plan(B, Hq, Hkv, page, n_cols, Dp,
                                       Dvp, Dep, q.dtype.itemsize)

    kernel = functools.partial(
        _paged_decode_kernel, hkv=Hkv, scale=float(scale), window=window,
        softcap=softcap, n_cols=n_cols, has_extra=has_extra)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B * Hkv, n_cols),
        in_specs=in_specs,
        out_specs=out_spec,
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, Dvp), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dvp), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        cost_estimate=cost,
        interpret=interpret,
    )(table, q_pos.reshape(B), *operands)
    return out.reshape(B, 1, Hq, Dvp)[..., :Dv]
