"""Pure-jnp oracles for every Pallas kernel — independent, direct
implementations used by the allclose test sweeps and as the CPU fallback.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  scale: float | None = None) -> jax.Array:
    """q: (B,Hq,S,D); k,v: (B,Hkv,T,D). Dense materialized softmax."""
    B, Hq, S, D = q.shape
    _, Hkv, T, _ = k.shape
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhst,bhtd->bhsd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def int8_quantize_ref(x: jax.Array, block: int = 256):
    flat = x.reshape(-1)
    pad = -flat.size % block
    flat = jnp.pad(flat, (0, pad))
    rows = flat.reshape(-1, block).astype(jnp.float32)
    scale = jnp.max(jnp.abs(rows), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(rows / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize_ref(q: jax.Array, scales: jax.Array, shape,
                        dtype=jnp.float32):
    flat = (q.astype(jnp.float32) * scales).reshape(-1)
    return flat[: math.prod(shape)].reshape(shape).astype(dtype)


def mamba_scan_ref(x, dt, b, c, a):
    """Direct sequential reference: x,dt (B,S,di); b,c (B,S,ds); a (di,ds)."""
    B, S, di = x.shape
    ds = b.shape[-1]
    a = a.astype(jnp.float32)

    def step(h, xs):
        x_t, dt_t, b_t, c_t = [t.astype(jnp.float32) for t in xs]
        dA = jnp.exp(dt_t[..., None] * a)
        h = dA * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    tm = lambda t: jnp.moveaxis(t, 1, 0)
    h0 = jnp.zeros((B, di, ds), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (tm(x), tm(dt), tm(b), tm(c)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def rwkv_scan_ref(r, k, v, w, u):
    """Direct sequential reference: r,k,v,w (B,S,H,hd); u (H,hd)."""
    B, S, H, hd = r.shape

    def step(Sst, xs):
        r_t, k_t, v_t, w_t = [t.astype(jnp.float32) for t in xs]
        kv = k_t[..., :, None] * v_t[..., None, :]
        o = jnp.einsum("bhi,bhij->bhj", r_t, Sst + u[None, :, :, None] * kv)
        return w_t[..., :, None] * Sst + kv, o

    tm = lambda t: jnp.moveaxis(t, 1, 0)
    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    _, os = jax.lax.scan(step, S0, (tm(r), tm(k), tm(v), tm(w)))
    return jnp.moveaxis(os, 0, 1).astype(r.dtype)
