"""Pallas TPU kernels for the linear-recurrence hot spots:

* ``mamba_scan``: Mamba selective scan — the (B, S, d_inner, d_state)
  hidden state is never materialized in HBM; each grid step keeps a
  (d_inner_block, d_state) state tile in VMEM scratch and walks a chunk
  of timesteps sequentially.
* ``rwkv_scan``: RWKV6 wkv recurrence with data-dependent decay — the
  per-head (head_dim, head_dim) state lives in VMEM scratch.

Grid layout (both): sequence chunks innermost + "arbitrary" so scratch
carries across chunks; batch/feature axes parallel.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import CompilerParams

DEFAULT_CHUNK = 64
DEFAULT_DI_BLOCK = 512


# ===========================================================================
# Mamba selective scan
# ===========================================================================

def _mamba_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, h_scr, *,
                  chunk: int):
    """Blocks: x,dt,y (1, chunk, bdi); b,c (1, chunk, ds); a (bdi, ds);
    scratch h (bdi, ds) f32. Grid (B, di_blocks, chunks)."""
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...]                                       # (bdi, ds) f32

    def step(t, h):
        x_t = x_ref[0, t, :].astype(jnp.float32)         # (bdi,)
        dt_t = dt_ref[0, t, :].astype(jnp.float32)       # (bdi,)
        b_t = b_ref[0, t, :].astype(jnp.float32)         # (ds,)
        c_t = c_ref[0, t, :].astype(jnp.float32)         # (ds,)
        dA = jnp.exp(dt_t[:, None] * a)                  # (bdi, ds)
        h = dA * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1)          # (bdi,)
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, chunk, step, h_scr[...])


def mamba_scan(x: jax.Array, dt: jax.Array, b: jax.Array, c: jax.Array,
               a: jax.Array, *, chunk: int = DEFAULT_CHUNK,
               di_block: int = DEFAULT_DI_BLOCK,
               interpret: bool = True) -> jax.Array:
    """x, dt: (B, S, di); b, c: (B, S, ds); a: (di, ds) [negative].
    Returns y: (B, S, di) with y_t = C_t · h_t,
    h_t = exp(dt_t·A)·h_{t-1} + (dt_t·x_t)·B_t."""
    B, S, di = x.shape
    ds = b.shape[-1]
    chunk = min(chunk, S)
    s_pad = -S % chunk
    bdi = min(di_block, di)
    di_pad = -di % bdi

    pad3 = lambda t: jnp.pad(t, ((0, 0), (0, s_pad), (0, di_pad)))
    xp, dtp = pad3(x), pad3(dt)
    bp = jnp.pad(b, ((0, 0), (0, s_pad), (0, 0)))
    cp = jnp.pad(c, ((0, 0), (0, s_pad), (0, 0)))
    ap = jnp.pad(a.astype(jnp.float32), ((0, di_pad), (0, 0)))
    Sp, dip = S + s_pad, di + di_pad
    n_chunks = Sp // chunk
    n_di = dip // bdi

    y = pl.pallas_call(
        functools.partial(_mamba_kernel, chunk=chunk),
        grid=(B, n_di, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, bdi), lambda ib, idi, ic: (ib, ic, idi)),
            pl.BlockSpec((1, chunk, bdi), lambda ib, idi, ic: (ib, ic, idi)),
            pl.BlockSpec((1, chunk, ds), lambda ib, idi, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, ds), lambda ib, idi, ic: (ib, ic, 0)),
            pl.BlockSpec((bdi, ds), lambda ib, idi, ic: (idi, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, bdi), lambda ib, idi, ic: (ib, ic, idi)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, dip), x.dtype),
        scratch_shapes=[pltpu.VMEM((bdi, ds), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, dtp, bp, cp, ap)
    return y[:, :S, :di]


# ===========================================================================
# RWKV6 wkv recurrence
# ===========================================================================

def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *,
                 chunk: int):
    """Blocks: r,k,v,w,o (1, chunk, hd); u (1, hd); scratch S (hd, hd) f32.
    Grid (B*H, chunks)."""
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[0].astype(jnp.float32)                     # (hd,)

    def step(t, S):
        r_t = r_ref[0, t, :].astype(jnp.float32)
        k_t = k_ref[0, t, :].astype(jnp.float32)
        v_t = v_ref[0, t, :].astype(jnp.float32)
        w_t = w_ref[0, t, :].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]                 # (hd, hd)
        o_t = jnp.sum(r_t[:, None] * (S + u[:, None] * kv), axis=0)
        o_ref[0, t, :] = o_t.astype(o_ref.dtype)
        return w_t[:, None] * S + kv

    s_scr[...] = jax.lax.fori_loop(0, chunk, step, s_scr[...])


def rwkv_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, *, chunk: int = DEFAULT_CHUNK,
              interpret: bool = True) -> jax.Array:
    """r,k,v,w: (B, S, H, hd); u: (H, hd).  Returns o: (B, S, H, hd) with
    o_t = r_t·(S_{t-1} + diag(u)·k_t v_tᵀ), S_t = diag(w_t)·S_{t-1} + k_t v_tᵀ."""
    B, S, H, hd = r.shape
    chunk = min(chunk, S)
    s_pad = -S % chunk
    tohb = lambda t: jnp.moveaxis(jnp.pad(t, ((0, 0), (0, s_pad), (0, 0), (0, 0))),
                                  2, 1).reshape(B * H, S + s_pad, hd)
    rp, kp, vp, wp = tohb(r), tohb(k), tohb(v), tohb(w)
    Sp = S + s_pad
    n_chunks = Sp // chunk

    o = pl.pallas_call(
        functools.partial(_rwkv_kernel, chunk=chunk),
        grid=(B * H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, hd), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, hd), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, hd), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, hd), lambda ib, ic: (ib, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda ib, ic: (ib, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rp, kp, vp, wp, jnp.tile(u, (B, 1)).reshape(B * H, hd))
    o = o[:, :S].reshape(B, H, S, hd)
    return jnp.moveaxis(o, 1, 2)
