"""Optimizers in raw JAX: AdamW with f32 master weights (mixed-precision
realism: model params may be bf16; moments and the master copy are f32),
SGD+momentum, global-norm clipping, LR schedules.

API: ``opt = adamw(...); state = opt.init(params);
new_params, state = opt.update(grads, state, params)``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def constant_lr(lr: float) -> Callable[[Array], Array]:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_lr(peak: float, warmup: int, total: int,
              floor: float = 0.0) -> Callable[[Array], Array]:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(1, warmup)
        t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return fn


def linear_lr(peak: float, warmup: int, total: int) -> Callable[[Array], Array]:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(1, warmup)
        t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        return jnp.where(step < warmup, warm, peak * (1.0 - t))
    return fn


# ---------------------------------------------------------------------------
# Gradient clipping
# ---------------------------------------------------------------------------

def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        tree), norm


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def _q8(x: Array):
    """Row-wise absmax int8 (one scale per trailing row).  Reshape-free on
    purpose: blockwise variants insert pad/reshape ops on sharded moments
    that re-seed GSPMD propagation badly (see EXPERIMENTS §Perf B).
    Returns (q int8 same shape, scales f32 (..., 1))."""
    xf = x.astype(jnp.float32)
    scales = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scales), -127, 127).astype(jnp.int8)
    return q, scales


def _dq8(q: Array, scales: Array) -> Array:
    return q.astype(jnp.float32) * scales


def adamw(lr: Callable | float, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          clip_norm: Optional[float] = 1.0,
          master_dtype=jnp.float32, state_bits: int = 32) -> Optimizer:
    """AdamW.  ``state_bits=8`` stores the moments as blockwise-int8
    (6.03 bytes/param of optimizer state instead of 12 — what makes
    llama3-405b training fit one v5e pod, §Perf hillclimb B)."""
    lr_fn = lr if callable(lr) else constant_lr(lr)
    q8 = state_bits == 8

    def _enc(x, sqrt_domain=False):
        if not q8:
            return x
        if sqrt_domain:                       # second moment: quantize
            x = jnp.sqrt(jnp.maximum(x, 0.0))  # sqrt(nu) — linear int8 on
        q, s = _q8(x)                          # the |g| scale, not g²
        return {"q": q, "s": s}

    def _dec(x, sqrt_domain=False):
        if not q8:
            return x
        v = _dq8(x["q"], x["s"])
        return jnp.square(v) if sqrt_domain else v

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(
                lambda p: _enc(jnp.zeros(p.shape, master_dtype)), params),
            "nu": jax.tree.map(
                lambda p: _enc(jnp.zeros(p.shape, master_dtype), True),
                params),
            "master": jax.tree.map(lambda p: p.astype(master_dtype), params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        gnorm = global_norm(grads)
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, mu, nu, m):
            g = g.astype(master_dtype)
            mu = b1 * _dec(mu) + (1 - b1) * g
            nu = b2 * _dec(nu, True) + (1 - b2) * jnp.square(g)
            u = (mu / c1) / (jnp.sqrt(nu / c2) + eps)
            m = m - lr_t * (u + weight_decay * m)
            return _enc(mu), _enc(nu, True), m

        flat_g, tdef = jax.tree.flatten(grads)
        is_enc = lambda x: q8 and isinstance(x, dict) and "q" in x
        flat_mu = tdef.flatten_up_to(state["mu"]) if not q8 else \
            jax.tree.leaves(state["mu"], is_leaf=is_enc)
        flat_nu = tdef.flatten_up_to(state["nu"]) if not q8 else \
            jax.tree.leaves(state["nu"], is_leaf=is_enc)
        flat_m = tdef.flatten_up_to(state["master"])
        out = [upd(g, mu, nu, m) for g, mu, nu, m
               in zip(flat_g, flat_mu, flat_nu, flat_m)]
        new_state = {
            "step": step,
            "mu": tdef.unflatten([o[0] for o in out]),
            "nu": tdef.unflatten([o[1] for o in out]),
            "master": tdef.unflatten([o[2] for o in out]),
        }
        new_params = jax.tree.map(lambda p, m: m.astype(p.dtype), params,
                                  new_state["master"])
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init, update)


def sgd(lr: Callable | float, *, momentum: float = 0.0,
        clip_norm: Optional[float] = None) -> Optimizer:
    lr_fn = lr if callable(lr) else constant_lr(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "vel": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                    params)}

    def update(grads, state, params):
        step = state["step"] + 1
        gnorm = global_norm(grads)
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        lr_t = lr_fn(step)

        def upd(p, g, v):
            v = momentum * v + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * v).astype(p.dtype), v

        flat = jax.tree.map(upd, params, grads, state["vel"])
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
        vel = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"step": step, "vel": vel}, \
            {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init, update)
