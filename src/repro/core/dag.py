"""FusionAI DAG intermediate representation (paper §3.5–3.6).

The IR plane: ML procedures (FP / BP / Update) are directed acyclic graphs
of operators.  Each ``OpNode`` carries the Table-2 attributes — name, op
users (forward edges), type (placeholder / variable / parametric /
non-parametric / loss), args (data dependencies), kwargs (constants) and,
after scheduling, a compnode location.  Sub-graphs (Table 3) are derived
views with inner/outer required data and outwards data computed from the
cut edges.

The IR is pure data (JSON-serializable) — execution lives in
``repro.core.executor`` (the execution plane), keeping the paper's
P3–P6 decoupling: any engine that can interpret the op vocabulary can run
a sub-DAG.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, asdict
from typing import Dict, List, Optional, Sequence, Tuple

# Op type taxonomy (paper Table 2)
PLACEHOLDER = "placeholder"     # inputs/labels — no grad, no params
VARIABLE = "variable"           # leaf tensors that require grad
PARAMETRIC = "parametric"       # ops with trainable parameters
NONPARAM = "nonparametric"      # stateless compute ops
LOSS = "loss"                   # loss functions (DAG sinks for FP)

OP_TYPES = (PLACEHOLDER, VARIABLE, PARAMETRIC, NONPARAM, LOSS)


@dataclass
class OpNode:
    """One operator in the IR plane."""
    name: str
    op: str                                  # op vocabulary id, e.g. "attention_block"
    op_type: str = NONPARAM
    args: Tuple[str, ...] = ()               # producer op names (data deps)
    kwargs: Dict = field(default_factory=dict)   # constants / config
    # analytic workload descriptors used by the perf model & scheduler:
    flops: float = 0.0                       # forward FLOPs
    param_bytes: float = 0.0                 # parameter storage
    out_bytes: float = 0.0                   # activation output size
    # filled by the scheduler:
    compnode: Optional[int] = None

    def __post_init__(self):
        assert self.op_type in OP_TYPES, self.op_type


class DAG:
    """Operator graph with Table-2/Table-3 derived attributes."""

    def __init__(self, name: str = "dag"):
        self.name = name
        self.nodes: Dict[str, OpNode] = {}
        self._order: List[str] = []          # insertion = topological order

    # -- construction -----------------------------------------------------
    def add(self, node: OpNode) -> OpNode:
        assert node.name not in self.nodes, f"duplicate op {node.name}"
        for a in node.args:
            assert a in self.nodes, f"{node.name}: unknown arg {a} (not topological)"
        self.nodes[node.name] = node
        self._order.append(node.name)
        return node

    # -- queries ------------------------------------------------------------
    def __len__(self):
        return len(self.nodes)

    def __contains__(self, name):
        return name in self.nodes

    def __getitem__(self, name) -> OpNode:
        return self.nodes[name]

    def topo_order(self) -> List[str]:
        return list(self._order)

    def users(self, name: str) -> List[str]:
        """OP users: ops that consume this op's output (forward edges)."""
        return [n for n in self._order if name in self.nodes[n].args]

    def edges(self) -> List[Tuple[str, str]]:
        return [(a, n) for n in self._order for a in self.nodes[n].args]

    def validate(self) -> None:
        """Topological consistency + acyclicity (insertion order is topo,
        so args must precede users)."""
        seen = set()
        for n in self._order:
            for a in self.nodes[n].args:
                assert a in seen, f"edge {a}->{n} violates topo order"
            seen.add(n)

    def total_flops(self) -> float:
        return sum(n.flops for n in self.nodes.values())

    def total_param_bytes(self) -> float:
        return sum(n.param_bytes for n in self.nodes.values())

    # -- Table-3 sub-graph view ---------------------------------------------
    def subgraph_attrs(self, assignment: Dict[str, int]) -> Dict[int, dict]:
        """Given op->compnode assignment, compute each sub-graph's Table-3
        attributes: nodes, inner/outer required data, outwards data and
        compnode users."""
        out: Dict[int, dict] = {}
        for name in self._order:
            node = self.nodes[name]
            k = assignment[name]
            g = out.setdefault(k, {"compnode": k, "nodes": [], "inner": set(),
                                   "outer": set(), "outwards": set(),
                                   "users": set()})
            g["nodes"].append(name)
        for name in self._order:
            k = assignment[name]
            for a in self.nodes[name].args:
                ka = assignment[a]
                if ka == k:
                    out[k]["inner"].add(a)
                else:
                    out[k]["outer"].add(a)          # data arriving from outside
                    out[ka]["outwards"].add(a)      # data leaving producer's graph
                    out[ka]["users"].add(k)
        return out

    def cut_bytes(self, assignment: Dict[str, int]) -> float:
        """Total bytes crossing sub-graph boundaries (each producer output
        counted once per remote consumer compnode, as the executor sends
        point-to-point)."""
        total = 0.0
        for name in self._order:
            src = assignment[name]
            remote = {assignment[u] for u in self.users(name)} - {src}
            total += self.nodes[name].out_bytes * len(remote)
        return total

    # -- serialization (IR plane is pure data) -------------------------------
    def to_json(self) -> str:
        return json.dumps({"name": self.name,
                           "nodes": [asdict(self.nodes[n]) for n in self._order]},
                          indent=1)

    @classmethod
    def from_json(cls, s: str) -> "DAG":
        d = json.loads(s)
        dag = cls(d["name"])
        for nd in d["nodes"]:
            nd["args"] = tuple(nd["args"])
            dag.add(OpNode(**nd))
        return dag


# ---------------------------------------------------------------------------
# DAG builders: model config -> FP DAG at Fig.-4 granularity
# ---------------------------------------------------------------------------

def build_model_dag(cfg, *, batch: int, seq: int, dtype_bytes: int = 2,
                    kind: str = "train") -> DAG:
    """Build the forward DAG of a ``ModelConfig`` at block granularity
    (embed, per-layer mixer block, per-layer FFN block, head, loss) — the
    same granularity as the paper's Fig. 4 (each transformer layer split
    into attention block and FFN block).

    Workload descriptors (flops / param_bytes / out_bytes) are analytic and
    feed the perf model (§3.7) and scheduler (§3.8).
    """
    from repro.core.workload import block_workloads

    dag = DAG(f"{cfg.name}-{kind}-fp")
    tok_bytes = batch * seq * 4
    act = batch * seq * cfg.d_model * dtype_bytes

    dag.add(OpNode("input", "input", PLACEHOLDER, out_bytes=tok_bytes))
    if kind == "train":
        dag.add(OpNode("label", "label", PLACEHOLDER, out_bytes=tok_bytes))
    w = block_workloads(cfg, batch=batch, seq=seq, dtype_bytes=dtype_bytes)
    dag.add(OpNode("embed", "embedding", PARAMETRIC, args=("input",),
                   flops=0.0, param_bytes=w["embed_params"] * dtype_bytes,
                   out_bytes=act))

    prev = "embed"
    layers = list(cfg.prefix_layers) + list(cfg.period) * (
        (cfg.n_layers - len(cfg.prefix_layers)) // max(1, len(cfg.period)))
    for i, spec in enumerate(layers):
        mixer = f"L{i}.{spec.mixer}"
        dag.add(OpNode(mixer, f"{spec.mixer}_block", PARAMETRIC, args=(prev,),
                       flops=w[f"{spec.mixer}_flops"],
                       param_bytes=w[f"{spec.mixer}_params"] * dtype_bytes,
                       out_bytes=act))
        ffn = f"L{i}.{spec.ffn}"
        dag.add(OpNode(ffn, f"{spec.ffn}_ffn", PARAMETRIC, args=(mixer,),
                       flops=w[f"{spec.ffn}_flops"],
                       param_bytes=w[f"{spec.ffn}_params"] * dtype_bytes,
                       out_bytes=act))
        prev = ffn

    dag.add(OpNode("head", "unembed", PARAMETRIC, args=(prev,),
                   flops=w["head_flops"],
                   param_bytes=w["head_params"] * dtype_bytes,
                   out_bytes=batch * seq * cfg.vocab_size * dtype_bytes))
    if kind == "train":
        dag.add(OpNode("loss", "cross_entropy", LOSS, args=("head", "label"),
                       kwargs={"weight": 1.0}, out_bytes=4))
    dag.validate()
    return dag
