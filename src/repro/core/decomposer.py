"""DAG decomposer (paper §3.1, §3.5): split the full operator DAG into
sub-DAGs that fit device memory and balance load.

Pipeline execution keeps sub-DAGs *contiguous* in topological order (the
paper runs sub-DAGs sequentially, §4).  Two partitioners:

* ``decompose_contiguous`` — K balanced contiguous cuts (DP, min–max of a
  per-block weight; exact).
* ``decompose_by_memory`` — greedy packing under a per-device memory
  budget (the "limited memory" driver of §1 challenge 1).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.dag import DAG, OpNode


def _block_weights(dag: DAG, weight: Optional[Callable[[OpNode], float]]
                   ) -> List[float]:
    weight = weight or (lambda n: n.flops)
    return [weight(dag[name]) for name in dag.topo_order()]


def decompose_contiguous(dag: DAG, k: int,
                         weight: Optional[Callable[[OpNode], float]] = None,
                         speeds: Optional[Sequence[float]] = None
                         ) -> List[List[str]]:
    """Partition topo order into ``k`` contiguous sub-DAGs minimizing the
    max (weight/speed) of any part — exact O(n²k) DP.

    ``speeds``: optional per-part device speeds (heterogeneous peers, in
    assignment order); defaults to uniform.
    """
    names = dag.topo_order()
    w = _block_weights(dag, weight)
    n = len(names)
    k = min(k, n)
    speeds = list(speeds) if speeds is not None else [1.0] * k
    assert len(speeds) >= k
    prefix = [0.0]
    for x in w:
        prefix.append(prefix[-1] + x)
    seg = lambda i, j: prefix[j] - prefix[i]          # weight of [i, j)

    INF = float("inf")
    # dp[p][i] = minimal max-load splitting first i blocks into p parts
    dp = [[INF] * (n + 1) for _ in range(k + 1)]
    cut = [[0] * (n + 1) for _ in range(k + 1)]
    dp[0][0] = 0.0
    for p in range(1, k + 1):
        sp = speeds[p - 1]
        for i in range(1, n + 1):
            # part p covers blocks [j, i)
            for j in range(p - 1, i):
                if dp[p - 1][j] == INF:
                    continue
                cand = max(dp[p - 1][j], seg(j, i) / sp)
                if cand < dp[p][i]:
                    dp[p][i] = cand
                    cut[p][i] = j
    parts: List[List[str]] = []
    i = n
    for p in range(k, 0, -1):
        j = cut[p][i]
        parts.append(names[j:i])
        i = j
    parts.reverse()
    return [p for p in parts if p]


def decompose_by_memory(dag: DAG, mem_limits: Sequence[float],
                        act_bytes: float = 0.0) -> List[List[str]]:
    """Greedy contiguous packing: walk the topo order, open a new sub-DAG
    when the next op's parameters would exceed the current device's budget
    (params + one activation buffer).  ``mem_limits`` cycles if shorter
    than needed."""
    names = dag.topo_order()
    parts: List[List[str]] = [[]]
    used = 0.0
    li = 0
    limit = mem_limits[0]
    for name in names:
        need = dag[name].param_bytes
        if parts[-1] and used + need + act_bytes > limit:
            parts.append([])
            used = 0.0
            li += 1
            limit = mem_limits[li % len(mem_limits)]
        parts[-1].append(name)
        used += need
    return parts


def assignment_of(parts: Sequence[Sequence[str]],
                  peers: Optional[Sequence[int]] = None) -> Dict[str, int]:
    """op name -> compnode id map from a partition (identity peer order by
    default)."""
    peers = list(peers) if peers is not None else list(range(len(parts)))
    return {name: peers[i] for i, part in enumerate(parts) for name in part}


def part_stats(dag: DAG, parts: Sequence[Sequence[str]]) -> List[dict]:
    out = []
    for part in parts:
        out.append({
            "n_ops": len(part),
            "flops": sum(dag[n].flops for n in part),
            "param_bytes": sum(dag[n].param_bytes for n in part),
            "out_bytes": dag[part[-1]].out_bytes if part else 0.0,
        })
    return out
