"""Analytic per-block workload descriptors (FLOPs, params) for any
``ModelConfig`` — the numbers that feed the DAG nodes, the PALEO perf
model (§3.7), the scheduler (§3.8) and the roofline's MODEL_FLOPS term.

Forward FLOPs conventions: matmul (m,k)x(k,n) = 2mkn; causal attention
scores counted at the causal 1/2 factor; backward = 2x forward
(grad-wrt-input + grad-wrt-weight).
"""
from __future__ import annotations

from typing import Dict


def block_workloads(cfg, *, batch: int, seq: int, dtype_bytes: int = 2,
                    kv_cache_len: int = 0) -> Dict[str, float]:
    """Per-block forward FLOPs and parameter counts.

    kv_cache_len > 0 switches attention score terms to decode mode
    (seq query tokens attending to a cache of that length).
    """
    d, T = cfg.d_model, batch * seq
    w: Dict[str, float] = {}

    # ---- attention (full or MLA) ---------------------------------------
    if cfg.n_heads:
        hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        if cfg.use_mla:
            qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
            dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
            params = (d * qr + qr * hq * (dn + dr) + d * (kr + dr)
                      + kr * hq * dn + kr * hq * dv + hq * dv * d)
            qk_dim, v_dim = dn + dr, dv
        else:
            params = d * hq * hd + 2 * d * hkv * hd + hq * hd * d
            qk_dim, v_dim = hd, hd
        proj_flops = 2.0 * T * params
        if kv_cache_len:
            score_ctx = kv_cache_len
            causal_factor = 1.0
        else:
            score_ctx = seq
            causal_factor = 0.5
        score = 2.0 * batch * seq * score_ctx * hq * (qk_dim + v_dim) * causal_factor
        w["attn_params"] = params
        w["attn_flops"] = proj_flops + score
        # sliding-window attention: context capped at the window
        sw = max(1, min(cfg.sliding_window or 1, score_ctx))
        sw_score = 2.0 * batch * seq * sw * hq * (qk_dim + v_dim)
        w["swa_params"] = params
        w["swa_flops"] = proj_flops + (min(sw_score, score) if cfg.sliding_window else score)

    # ---- mamba -----------------------------------------------------------
    di, ds, dtr = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_dt_rank
    m_params = (d * 2 * di + di * cfg.mamba_d_conv + di * (dtr + 2 * ds)
                + dtr * di + di * ds + di * d)
    scan_flops = T * di * ds * 10.0          # exp, 2 mul-adds, reduce per (di,ds)
    w["mamba_params"] = m_params
    w["mamba_flops"] = 2.0 * T * m_params + scan_flops

    # ---- rwkv6 -----------------------------------------------------------
    if cfg.d_model % cfg.rwkv_head_dim == 0:
        H, hd_r = cfg.rwkv_n_heads, cfg.rwkv_head_dim
        r_params = 5 * d * d + d * cfg.rwkv_decay_lora * 2 + H * hd_r
        w["rwkv_params"] = r_params
        w["rwkv_flops"] = 2.0 * T * r_params + T * H * hd_r * hd_r * 6.0

    # ---- FFNs ------------------------------------------------------------
    w["dense_params"] = 3.0 * d * cfg.d_ff
    w["dense_flops"] = 2.0 * T * w["dense_params"]
    if cfg.n_experts:
        e_params = cfg.n_experts * 3.0 * d * cfg.d_expert
        sh_params = cfg.n_shared_experts * 3.0 * d * cfg.d_expert
        w["moe_params"] = e_params + sh_params + d * cfg.n_experts
        w["moe_flops"] = (2.0 * T * 3.0 * d * cfg.d_expert
                          * (cfg.top_k + cfg.n_shared_experts)
                          + 2.0 * T * d * cfg.n_experts)
    # ---- embed / head ------------------------------------------------------
    w["embed_params"] = cfg.vocab_size * d
    w["head_params"] = 0.0 if cfg.tie_embeddings else cfg.vocab_size * d
    w["head_flops"] = 2.0 * T * d * cfg.vocab_size
    return w


def model_flops(cfg, *, batch: int, seq: int, kind: str = "train",
                kv_cache_len: int = 0) -> float:
    """End-to-end step FLOPs: the 'useful compute' roofline numerator.
    train = 3x forward (fwd + 2x bwd); prefill/decode = forward only."""
    w = block_workloads(cfg, batch=batch, seq=seq, kv_cache_len=kv_cache_len)
    layers = list(cfg.prefix_layers) + list(cfg.period) * (
        (cfg.n_layers - len(cfg.prefix_layers)) // max(1, len(cfg.period)))
    fwd = w["head_flops"]
    for spec in layers:
        fwd += w[f"{spec.mixer}_flops"] + w[f"{spec.ffn}_flops"]
    return 3.0 * fwd if kind == "train" else fwd


def model_flops_6nd(cfg, *, tokens: int) -> float:
    """The classic 6·N·D (dense) / 6·N_active·D (MoE) estimate."""
    return 6.0 * cfg.param_counts()["active"] * tokens


REMAT_FACTORS = {
    # fraction of the forward recomputed during backward
    "full": 1.0,          # checkpoint everything per period
    "dots": 1.0 / 3.0,    # matmul outputs saved; elementwise/norm recomputed
    "dots_no_batch": 0.5,
    "none": 0.0,
}


def step_flops(cfg, *, batch: int, seq: int, kind: str,
               kv_cache_len: int = 0, remat: bool = True,
               remat_policy: str = "full") -> float:
    """Executed FLOPs per step including rematerialization overhead
    (train = fwd + recompute·fwd + 2×fwd for bwd)."""
    fwd = model_flops(cfg, batch=batch, seq=seq, kind="prefill",
                      kv_cache_len=kv_cache_len)
    if kind == "train":
        rf = REMAT_FACTORS[remat_policy] if remat else 0.0
        return (3.0 + rf) * fwd
    return fwd


def cache_bytes(cfg, *, batch: int, cache_len: int, dtype_bytes: int = 2
                ) -> float:
    """Total decode-state bytes across all layers (KV / MLA latent / SSM)."""
    layers = list(cfg.prefix_layers) + list(cfg.period) * (
        (cfg.n_layers - len(cfg.prefix_layers)) // max(1, len(cfg.period)))
    total = 0.0
    for spec in layers:
        if spec.mixer == "attn":
            if cfg.use_mla:
                total += batch * cache_len * (cfg.kv_lora_rank
                                              + cfg.qk_rope_dim) * dtype_bytes
            else:
                total += 2 * batch * cache_len * cfg.n_kv_heads \
                    * cfg.head_dim * dtype_bytes
        elif spec.mixer == "swa":
            w = min(cfg.sliding_window or cache_len, cache_len)
            total += 2 * batch * w * cfg.n_kv_heads * cfg.head_dim * dtype_bytes
        elif spec.mixer == "mamba":
            total += batch * cfg.mamba_d_inner * (cfg.mamba_d_state * 4
                                                  + (cfg.mamba_d_conv - 1) * dtype_bytes)
        elif spec.mixer == "rwkv":
            total += batch * cfg.d_model * cfg.rwkv_head_dim * 4 \
                + batch * cfg.d_model * dtype_bytes
    return total


def analytic_hbm_bytes(cfg, *, batch: int, seq: int, kind: str,
                       kv_cache_len: int = 0) -> float:
    """Estimated global HBM traffic per step (documented estimate, used
    for the roofline memory term; XLA's module counter can't be used
    because while-loop bodies are counted once).

    train:  params 3 reads (fwd/remat/bwd) + f32 grads W+R + Adam state
            R+W (master+mu+nu) + param write  ≈ 40·N bytes,
            activations ≈ 12 passes of n_layers·B·S·d·2B,
            logits ≈ 4·B·S·V bytes (chunked, recomputed once).
    prefill: params read + activation writes + KV write + KV re-read.
    decode:  params read (all experts touched by dense-buffer MoE
             dispatch) + full cache read + cache write.
    """
    N = cfg.param_counts()["total"]
    d = cfg.d_model
    acts = cfg.n_layers * batch * seq * d * 2.0
    if kind == "train":
        logits = 4.0 * batch * seq * cfg.vocab_size
        return 40.0 * N + 12.0 * acts + logits
    if kind == "prefill":
        cb = cache_bytes(cfg, batch=batch, cache_len=seq)
        return 2.0 * N + 4.0 * acts + 2.0 * cb
    # decode
    cb = cache_bytes(cfg, batch=batch, cache_len=kv_cache_len or seq)
    return 2.0 * N + 2.0 * cb
