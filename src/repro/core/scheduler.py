"""Task scheduling (paper §3.8, Eq. 2):

    min_A  max_p  Σ_{k ∈ A_p} T(G_{S_k})
    s.t.   per-peer GPU / CPU / disk memory constraints.

The assignment problem is NP-hard (it contains multiprocessor scheduling);
we solve it the way production schedulers do: LPT greedy over
heterogeneous speeds + local-search refinement (move / swap), both purely
deterministic.  For pipeline execution order, ``schedule_pipeline`` keeps
sub-DAGs contiguous and maps them onto the fastest feasible peers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.dag import DAG
from repro.core.perfmodel import CompNode, PerfModel


@dataclass
class Task:
    """One schedulable sub-DAG."""
    task_id: int
    op_names: Tuple[str, ...]
    flops: float
    gpu_bytes: float
    cpu_bytes: float = 0.0
    disk_bytes: float = 0.0
    in_bytes: float = 0.0           # activation arriving from the previous stage
    out_bytes: float = 0.0          # activation leaving this stage


def tasks_from_parts(dag: DAG, parts: Sequence[Sequence[str]],
                     act_multiplier: float = 2.0) -> List[Task]:
    """Build Task records from a contiguous partition.  ``act_multiplier``
    accounts for activations kept alive alongside params (fwd + grad)."""
    tasks = []
    for i, part in enumerate(parts):
        params = sum(dag[n].param_bytes for n in part)
        act = max((dag[n].out_bytes for n in part), default=0.0)
        first_args = [a for a in dag[part[0]].args] if part else []
        in_bytes = sum(dag[a].out_bytes for a in first_args)
        tasks.append(Task(
            task_id=i, op_names=tuple(part),
            flops=sum(dag[n].flops for n in part),
            gpu_bytes=params + act_multiplier * act,
            cpu_bytes=params,           # host copy for checkpoint/restart
            disk_bytes=params,
            in_bytes=in_bytes,
            out_bytes=dag[part[-1]].out_bytes if part else 0.0))
    return tasks


@dataclass
class Schedule:
    assignment: Dict[int, int]          # task_id -> node_id
    loads: Dict[int, float]             # node_id -> total time
    feasible: bool

    @property
    def makespan(self) -> float:
        return max(self.loads.values()) if self.loads else 0.0


def _fits(task: Task, node: CompNode, used: Dict[int, List[float]]) -> bool:
    g, c, d = used[node.node_id]
    return node.memory_ok(g + task.gpu_bytes, c + task.cpu_bytes,
                          d + task.disk_bytes)


def schedule_loadbalance(tasks: Sequence[Task], nodes: Sequence[CompNode],
                         refine_iters: int = 200,
                         init_loads: Optional[Dict[int, float]] = None,
                         init_used: Optional[Dict[int, Sequence[float]]] = None
                         ) -> Schedule:
    """Eq. 2 solver: LPT greedy + move/swap local search.

    ``init_loads`` seeds each node's starting load (node_id -> seconds of
    already-assigned work) and ``init_used`` its starting memory
    footprint (node_id -> [gpu, cpu, disk] bytes), so a reschedule after
    churn balances NEW tasks against survivors' existing commitments —
    time AND memory — instead of pretending every peer is idle.  The
    returned ``Schedule.loads`` includes the seed, so its makespan is the
    true fleet makespan."""
    nodes = [n for n in nodes if n.online]
    used = {n.node_id: list((init_used or {}).get(n.node_id, (0.0, 0.0, 0.0)))
            for n in nodes}
    loads = {n.node_id: float((init_loads or {}).get(n.node_id, 0.0))
             for n in nodes}
    byid = {n.node_id: n for n in nodes}
    assignment: Dict[int, int] = {}
    feasible = True

    def task_time(t: Task, n: CompNode) -> float:
        return t.flops / n.speed

    for t in sorted(tasks, key=lambda t: -t.flops):
        best = None
        for n in nodes:
            if not _fits(t, n, used):
                continue
            cand = loads[n.node_id] + task_time(t, n)
            if best is None or cand < best[0]:
                best = (cand, n)
        if best is None:                      # no feasible peer: overflow to
            feasible = False                  # least-loaded (report infeasible)
            best = (min(loads.values()), min(nodes, key=lambda n: loads[n.node_id]))
        n = best[1]
        assignment[t.task_id] = n.node_id
        loads[n.node_id] += task_time(t, n)
        used[n.node_id][0] += t.gpu_bytes
        used[n.node_id][1] += t.cpu_bytes
        used[n.node_id][2] += t.disk_bytes

    # ---- local search: move single tasks off the argmax peer --------------
    tmap = {t.task_id: t for t in tasks}
    for _ in range(refine_iters):
        worst = max(loads, key=loads.get)
        moved = False
        for tid, nid in sorted(assignment.items(),
                               key=lambda kv: -tmap[kv[0]].flops):
            if nid != worst:
                continue
            t = tmap[tid]
            for n in nodes:
                if n.node_id == worst or not _fits(t, n, used):
                    continue
                new_dst = loads[n.node_id] + task_time(t, n)
                new_src = loads[worst] - task_time(t, byid[worst])
                if max(new_dst, new_src) < loads[worst] - 1e-12:
                    assignment[tid] = n.node_id
                    loads[n.node_id] = new_dst
                    loads[worst] = new_src
                    for i, v in enumerate([t.gpu_bytes, t.cpu_bytes, t.disk_bytes]):
                        used[worst][i] -= v
                        used[n.node_id][i] += v
                    moved = True
                    break
            if moved:
                break
        if not moved:
            break
    return Schedule(assignment, loads, feasible)


def schedule_pipeline(tasks: Sequence[Task], nodes: Sequence[CompNode]
                      ) -> Schedule:
    """Contiguous pipeline mapping: stage i starts at the i-th peer of a
    speed-sorted peer list (stages are already balanced by the decomposer
    against these speeds) and skips forward, wrapping, to the next peer
    with enough free memory — cumulative across the stages a peer already
    holds.  Only when NO peer can fit a stage is it force-placed on its
    preferred peer and the schedule marked infeasible."""
    nodes = sorted([n for n in nodes if n.online], key=lambda n: -n.speed)
    used = {n.node_id: [0.0, 0.0, 0.0] for n in nodes}
    assignment, loads = {}, {n.node_id: 0.0 for n in nodes}
    feasible = len(nodes) >= len(tasks)
    for t in tasks:
        start = t.task_id % len(nodes)
        n = None
        for j in range(len(nodes)):
            cand = nodes[(start + j) % len(nodes)]
            if _fits(t, cand, used):
                n = cand
                break
        if n is None:
            feasible = False
            n = nodes[start]
        assignment[t.task_id] = n.node_id
        loads[n.node_id] += t.flops / n.speed
        used[n.node_id][0] += t.gpu_bytes
        used[n.node_id][1] += t.cpu_bytes
        used[n.node_id][2] += t.disk_bytes
    return Schedule(assignment, loads, feasible)
