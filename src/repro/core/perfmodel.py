"""Analytic hardware performance model (paper §3.3, §3.7).

* Device catalog (Table 1 + TPU targets) with peak tensor FLOPS and
  memory capacities.
* PALEO-style per-op time:  T(f,p) = R(Pa(f)) + C(f,p) + W(f,p)
  with C = FLOPs(f) / S(p),  S(p) = S*(p) · λ_p.
* alpha-beta point-to-point communication:  T_comm(M) = α + β·M.
* λ_p fitted from short profiling runs by least squares (§3.7).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional, Sequence, Tuple

GB = 1e9


@dataclass(frozen=True)
class DeviceSpec:
    """Peak specs. ``tflops`` is the tensor-core rate the paper uses for
    its estimates (Table 1 'TFLOPS FP32 Tensor Core'; bf16 for TPUs)."""
    name: str
    tflops: float                 # peak tensor TFLOP/s
    gpu_mem: float                # bytes
    cpu_mem: float = 32 * GB
    disk: float = 512 * GB
    mem_bw: float = 500e9         # HBM/GDDR bytes/s
    price_usd: float = 0.0
    level: str = "consumer"

    @property
    def flops(self) -> float:
        return self.tflops * 1e12


# Table 1 of the paper + the TPU target used by the production mesh.
DEVICE_CATALOG: Dict[str, DeviceSpec] = {d.name: d for d in [
    DeviceSpec("rtx4090", 82.58, 24 * GB, mem_bw=1008e9, price_usd=1599, level="consumer"),
    DeviceSpec("rtx4080", 97.5, 16 * GB, mem_bw=717e9, price_usd=1199, level="consumer"),
    DeviceSpec("rtx3080", 59.5, 10 * GB, mem_bw=760e9, price_usd=699, level="consumer"),
    DeviceSpec("h100", 756.0, 80 * GB, mem_bw=3350e9, price_usd=30000, level="datacenter"),
    DeviceSpec("a100", 155.92, 80 * GB, mem_bw=2039e9, price_usd=15000, level="datacenter"),
    DeviceSpec("v100", 125.0, 32 * GB, mem_bw=900e9, price_usd=10000, level="datacenter"),
    DeviceSpec("tpu_v5e", 197.0, 16 * GB, mem_bw=819e9, price_usd=0, level="datacenter"),
]}


@dataclass(frozen=True)
class LinkSpec:
    """alpha-beta link: T(M) = alpha + beta * M  (beta = 1/bandwidth)."""
    alpha: float                  # seconds
    beta: float                   # seconds / byte

    @classmethod
    def from_bandwidth(cls, bw_bytes_per_s: float, latency_s: float = 1e-3):
        return cls(alpha=latency_s, beta=1.0 / bw_bytes_per_s)

    def time(self, message_bytes: float) -> float:
        return self.alpha + self.beta * message_bytes if message_bytes > 0 else 0.0


# Named WAN/LAN regimes used in the paper's Fig. 5/6 sweeps.
LINK_REGIMES: Dict[str, LinkSpec] = {
    "wan_10mbps": LinkSpec.from_bandwidth(10e6 / 8, 50e-3),
    "wan_100mbps": LinkSpec.from_bandwidth(100e6 / 8, 20e-3),
    "wan_1gbps": LinkSpec.from_bandwidth(1e9 / 8, 10e-3),
    "lan_10gbps": LinkSpec.from_bandwidth(10e9 / 8, 0.1e-3),
    "nvlink": LinkSpec.from_bandwidth(450e9, 5e-6),
    "tpu_ici": LinkSpec.from_bandwidth(50e9, 1e-6),
}


def fit_lambda(flops_samples: Sequence[float], time_samples: Sequence[float],
               peak_flops: float) -> float:
    """Regression-based scaling-down factor λ_p (§3.7, after PALEO).

    Model t = f / (S*·λ); least squares of t against x = f/S* through the
    origin gives 1/λ = Σ x·t / Σ x²."""
    xs = [f / peak_flops for f in flops_samples]
    num = sum(x * t for x, t in zip(xs, time_samples))
    den = sum(x * x for x in xs)
    if den <= 0 or num <= 0:
        return 1.0
    lam = den / num  # λ = 1 / c, c = num/den
    return min(1.0, lam)


@dataclass
class CompNode:
    """A computing provider (paper §3.3): device + link + collaboration
    dynamics. ``kind`` distinguishes long-lived supernodes from transient
    antnodes."""
    node_id: int
    device: DeviceSpec
    link: LinkSpec
    lam: float = 0.75             # λ_p scaling-down factor
    kind: str = "antnode"         # supernode | antnode
    reliability: float = 0.999    # per-heartbeat survival probability
    online: bool = True

    @property
    def speed(self) -> float:
        return self.device.flops * self.lam

    def compute_time(self, flops: float) -> float:
        return flops / self.speed

    def memory_ok(self, gpu_bytes: float, cpu_bytes: float = 0.0,
                  disk_bytes: float = 0.0) -> bool:
        return (gpu_bytes <= self.device.gpu_mem
                and cpu_bytes <= self.device.cpu_mem
                and disk_bytes <= self.device.disk)


class PerfModel:
    """PALEO-style op/sub-graph timing over a set of compnodes."""

    def __init__(self, nodes: Sequence[CompNode]):
        self.nodes = {n.node_id: n for n in nodes}

    def link(self, src: int, dst: int) -> LinkSpec:
        """Point-to-point link: dominated by the slower endpoint's uplink
        (alpha adds, beta takes the max ≙ min bandwidth)."""
        a, b = self.nodes[src].link, self.nodes[dst].link
        return LinkSpec(alpha=a.alpha + b.alpha, beta=max(a.beta, b.beta))

    def op_time(self, node, peer_id: int,
                parent_locs: Optional[Dict[str, int]] = None,
                parent_bytes: Optional[Dict[str, float]] = None) -> float:
        """T(f,p) = R(Pa(f)) + C(f,p) + W(f,p)  (Eq. 1)."""
        p = self.nodes[peer_id]
        c = p.compute_time(node.flops)
        w = node.out_bytes / p.device.mem_bw
        r = 0.0
        if parent_locs:
            for a in node.args:
                src = parent_locs.get(a, peer_id)
                if src != peer_id:
                    r += self.link(src, peer_id).time(
                        (parent_bytes or {}).get(a, 0.0))
        return r + c + w

    def subgraph_time(self, dag, op_names: Sequence[str], peer_id: int,
                      assignment: Optional[Dict[str, int]] = None
                      ) -> Tuple[float, float]:
        """Sequential-execution time of a sub-graph on a peer, split into
        (compute C_p, receive R_p) — the Eq. 3 terms.  The sequential sum
        is the upper end of the paper's [max_i T, Σ_i T] range."""
        comp = 0.0
        recv = 0.0
        for name in op_names:
            node = dag[name]
            p = self.nodes[peer_id]
            comp += p.compute_time(node.flops) + node.out_bytes / p.device.mem_bw
            if assignment:
                for a in node.args:
                    src = assignment.get(a, peer_id)
                    if src != peer_id:
                        recv += self.link(src, peer_id).time(dag[a].out_bytes)
        return comp, recv


def make_fleet(spec: Iterable[Tuple[str, int]], link: LinkSpec,
               lam: float = 0.75, seed: int = 0) -> list:
    """Build a homogeneous-link fleet, e.g. make_fleet([("rtx3080", 50)],
    LINK_REGIMES["wan_1gbps"])."""
    nodes = []
    nid = 0
    for dev_name, count in spec:
        for _ in range(count):
            nodes.append(CompNode(nid, DEVICE_CATALOG[dev_name], link, lam=lam))
            nid += 1
    return nodes
