"""Pipeline performance analysis (paper §4, Eqs. 3–4) + a discrete-event
pipeline simulator that validates the closed forms.

    T_lat(G)        = Σ_p (C_p + R_p)                         (Eq. 3)
    T_pipe(G, n_b)  = Σ_p (C_p + R_p) + (n_b − 1)·max_p max(C_p, R_p)   (Eq. 4)

C_p: compute time of peer p's sub-DAGs; R_p: receive (communication) time
of cut edges into p.  The simulator plays the GPipe-style schedule
t[p][j] = max(t[p-1][j] + r_p, t[p][j-1]) + c_p and reports the true
makespan, which the closed form approximates from above/below.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.dag import DAG
from repro.core.perfmodel import PerfModel


@dataclass
class StageTimes:
    """Per-pipeline-stage compute (C_p) and receive (R_p) seconds."""
    compute: List[float]
    receive: List[float]

    @property
    def n_stages(self) -> int:
        return len(self.compute)


def stage_times(dag: DAG, parts: Sequence[Sequence[str]],
                perf: PerfModel, peer_order: Sequence[int]) -> StageTimes:
    """Evaluate C_p and R_p for each contiguous sub-DAG on its peer."""
    assignment = {name: peer_order[i]
                  for i, part in enumerate(parts) for name in part}
    cs, rs = [], []
    for i, part in enumerate(parts):
        c, r = perf.subgraph_time(dag, part, peer_order[i], assignment)
        cs.append(c)
        rs.append(r)
    return StageTimes(cs, rs)


def latency_eq3(st: StageTimes) -> float:
    return sum(st.compute) + sum(st.receive)


def pipelined_eq4(st: StageTimes, n_batches: int) -> float:
    bottleneck = max(max(c, r) for c, r in zip(st.compute, st.receive))
    return latency_eq3(st) + (n_batches - 1) * bottleneck


def throughput_eq4(st: StageTimes, n_batches: int, batch_size: int) -> float:
    """Samples/second at steady state."""
    return n_batches * batch_size / pipelined_eq4(st, n_batches)


def simulate_pipeline(st: StageTimes, n_batches: int) -> float:
    """Discrete-event makespan of the FP pipeline.  Each stage has two
    serialized resources — its inbound link (service r_p) and its device
    (service c_p) — matching the paper's model where (n_b-1)·max(C_p,R_p)
    is the steady-state increment.  Microbatch j enters stage p's link
    once stage p-1 finished j and the link is free; compute starts when
    the transfer lands and the device is free."""
    P = st.n_stages
    prev_row = [0.0] * n_batches
    finish = 0.0
    for p in range(P):
        row = []
        link_free = 0.0
        dev_free = 0.0
        for j in range(n_batches):
            arrive = max(prev_row[j] if p else 0.0, link_free) + st.receive[p]
            link_free = arrive
            dev_free = max(arrive, dev_free) + st.compute[p]
            row.append(dev_free)
        prev_row = row
        finish = dev_free
    return finish


def bubble_fraction(st: StageTimes, n_batches: int) -> float:
    """Fraction of total device-time lost to pipeline bubbles."""
    makespan = simulate_pipeline(st, n_batches)
    busy = sum(st.compute) * n_batches
    return 1.0 - busy / (makespan * st.n_stages)


# ---------------------------------------------------------------------------
# End-to-end estimator used by the Fig. 5/6 reproduction benchmarks
# ---------------------------------------------------------------------------

def estimate_system(dag: DAG, perf: PerfModel, peer_ids: Sequence[int],
                    n_batches: int, batch_size: int,
                    weight=None) -> Dict[str, float]:
    """Partition ``dag`` across ``peer_ids`` (contiguous, speed-aware DP),
    evaluate Eq. 3/4 and the simulator, and report latency/throughput."""
    from repro.core.decomposer import decompose_contiguous

    speeds = [perf.nodes[p].speed for p in peer_ids]
    parts = decompose_contiguous(dag, len(peer_ids), weight=weight,
                                 speeds=speeds)
    order = list(peer_ids)[:len(parts)]
    st = stage_times(dag, parts, perf, order)
    lat = latency_eq3(st)
    pipe = pipelined_eq4(st, n_batches)
    sim = simulate_pipeline(st, n_batches)
    return {
        "n_stages": float(len(parts)),
        "latency_s": lat,
        "pipelined_s_eq4": pipe,
        "pipelined_s_sim": sim,
        "throughput_samples_s": n_batches * batch_size / pipe,
        "throughput_samples_s_sim": n_batches * batch_size / sim,
        "bubble_fraction": bubble_fraction(st, n_batches),
        "bottleneck_s": max(max(c, r) for c, r in zip(st.compute, st.receive)),
    }
