"""Communication-efficiency toolbox (paper §2.3) — the techniques FusionAI
"incorporates and conducts scheduling with": top-k sparsification (with
error feedback), QSGD-style stochastic quantization, deterministic int8
block quantization (backed by the Pallas kernel for the hot path), and
local-SGD step gating.

Every transform is a pair (encode, decode) plus an analytic
``compressed_bytes`` used by the scheduler/perf-model to price
communication on slow links.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Top-k sparsification (+ error feedback)
# ---------------------------------------------------------------------------

def topk_encode(g: Array, ratio: float) -> Tuple[Array, Array]:
    """Keep the top ``ratio`` fraction by magnitude. Returns (values, idx)."""
    flat = g.reshape(-1)
    k = max(1, int(flat.size * ratio))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_decode(values: Array, idx: Array, shape) -> Array:
    flat = jnp.zeros(math.prod(shape), values.dtype).at[idx].set(values)
    return flat.reshape(shape)


def topk_bytes(n_elems: int, ratio: float, value_bytes: int = 4,
               index_bytes: int = 4) -> float:
    k = max(1, int(n_elems * ratio))
    return k * (value_bytes + index_bytes)


@dataclass
class ErrorFeedback:
    """EF-SGD memory: residual of what compression dropped, re-added next
    round.  Usage: state = ef.init(g); g_hat, state = ef.step(g, state)."""
    ratio: float

    def init(self, g: Array) -> Array:
        return jnp.zeros_like(g)

    def step(self, g: Array, residual: Array) -> Tuple[Array, Array]:
        corrected = g + residual
        vals, idx = topk_encode(corrected, self.ratio)
        sent = topk_decode(vals, idx, corrected.shape)
        return sent, corrected - sent


# ---------------------------------------------------------------------------
# QSGD stochastic quantization
# ---------------------------------------------------------------------------

def qsgd_encode(key, g: Array, levels: int = 256) -> Tuple[Array, Array]:
    """Stochastic uniform quantization to ``levels`` buckets per tensor.
    Returns (codes uint8/16, scale). Unbiased: E[decode] = g."""
    scale = jnp.max(jnp.abs(g)) + 1e-12
    y = jnp.abs(g) / scale * (levels - 1)
    lo = jnp.floor(y)
    p = y - lo
    up = jax.random.bernoulli(key, p, g.shape)
    q = (lo + up.astype(jnp.float32)).astype(jnp.uint8 if levels <= 256
                                             else jnp.uint16)
    sign = jnp.signbit(g)
    return jnp.where(sign, -q.astype(jnp.int32), q.astype(jnp.int32)), scale


def qsgd_decode(codes: Array, scale: Array, levels: int = 256) -> Array:
    return codes.astype(jnp.float32) * scale / (levels - 1)


def qsgd_bytes(n_elems: int, levels: int = 256) -> float:
    bits = max(1, math.ceil(math.log2(levels))) + 1      # + sign bit
    return n_elems * bits / 8 + 4                        # + f32 scale


# ---------------------------------------------------------------------------
# Deterministic int8 block quantization (all-reduce payload compression)
# ---------------------------------------------------------------------------

def int8_block_encode(g: Array, block: int = 256) -> Tuple[Array, Array]:
    """Per-block absmax int8. Returns (q int8 (n_blocks, block), scales)."""
    flat = g.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    scales = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scales), -127, 127).astype(jnp.int8)
    return q, scales


def int8_block_decode(q: Array, scales: Array, shape) -> Array:
    flat = (q.astype(jnp.float32) * scales).reshape(-1)
    return flat[: math.prod(shape)].reshape(shape)


def int8_bytes(n_elems: int, block: int = 256) -> float:
    n_blocks = math.ceil(n_elems / block)
    return n_elems + n_blocks * 4


# ---------------------------------------------------------------------------
# Local SGD (§2.3): communicate every H steps
# ---------------------------------------------------------------------------

@dataclass
class LocalSGD:
    """Step gate: sync model average every ``period`` local steps.  The
    effective per-step communication volume shrinks by 1/period, which is
    what the scheduler prices."""
    period: int

    def should_sync(self, step: int) -> bool:
        return (step + 1) % self.period == 0

    def bytes_per_step(self, model_bytes: float) -> float:
        return model_bytes / self.period


# ---------------------------------------------------------------------------
# Registry used by the scheduler to price links
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CompressionSpec:
    kind: str            # none | topk | qsgd | int8 | local_sgd
    ratio: float = 0.01  # topk keep-ratio
    levels: int = 256    # qsgd levels
    period: int = 8      # local-sgd period

    def bytes(self, n_elems: int, raw_bytes: Optional[float] = None) -> float:
        raw = raw_bytes if raw_bytes is not None else 4.0 * n_elems
        if self.kind == "none":
            return raw
        if self.kind == "topk":
            return topk_bytes(n_elems, self.ratio)
        if self.kind == "qsgd":
            return qsgd_bytes(n_elems, self.levels)
        if self.kind == "int8":
            return int8_bytes(n_elems)
        if self.kind == "local_sgd":
            return raw / self.period
        raise ValueError(self.kind)
