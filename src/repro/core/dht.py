"""Distributed Hash Table (paper §3.4, §3.9): decentralized key-value
storage for datasets, activations and checkpoints.

Consistent hashing ring with virtual nodes + replication.  This is a
faithful single-process simulation of the paper's DHT layer: each
compnode hosts a shard of the ring; lookups route by key hash; node
failures lose only the shards whose every replica died.
"""
from __future__ import annotations

import bisect
import hashlib
from typing import Any, Dict, List, Optional, Sequence


def _h(key: str) -> int:
    return int.from_bytes(hashlib.sha1(key.encode()).digest()[:8], "big")


class DHT:
    def __init__(self, node_ids: Sequence[int], *, virtual: int = 32,
                 replication: int = 2):
        self.virtual = virtual
        self.replication = replication
        self._ring: List[tuple] = []              # (hash, node_id)
        self._stores: Dict[int, Dict[str, Any]] = {}
        for nid in node_ids:
            self.join(nid)

    # -- membership ---------------------------------------------------------
    def join(self, node_id: int) -> None:
        if node_id in self._stores:
            return
        self._stores[node_id] = {}
        for v in range(self.virtual):
            bisect.insort(self._ring, (_h(f"n{node_id}#{v}"), node_id))

    def leave(self, node_id: int) -> None:
        """Node failure: its store vanishes; ring entries removed."""
        self._stores.pop(node_id, None)
        self._ring = [(h, n) for h, n in self._ring if n != node_id]

    @property
    def nodes(self) -> List[int]:
        return list(self._stores)

    # -- routing --------------------------------------------------------------
    def owners(self, key: str) -> List[int]:
        """First ``replication`` distinct nodes clockwise from hash(key)."""
        if not self._ring:
            return []
        i = bisect.bisect_left(self._ring, (_h(key), -1)) % len(self._ring)
        seen: List[int] = []
        j = i
        while len(seen) < min(self.replication, len(self._stores)):
            nid = self._ring[j % len(self._ring)][1]
            if nid not in seen:
                seen.append(nid)
            j += 1
        return seen

    # -- data plane -------------------------------------------------------------
    def put(self, key: str, value: Any) -> List[int]:
        owners = self.owners(key)
        for nid in owners:
            self._stores[nid][key] = value
        return owners

    def get(self, key: str) -> Optional[Any]:
        for nid in self.owners(key):
            if key in self._stores.get(nid, {}):
                return self._stores[nid][key]
        # replicas may have moved after churn: fall back to a full scan
        for store in self._stores.values():
            if key in store:
                return store[key]
        return None

    def rebalance(self, key_iter: Optional[Sequence[str]] = None) -> int:
        """Re-replicate keys whose owner set changed after churn; returns
        number of copies made."""
        copies = 0
        all_keys = set()
        for store in self._stores.values():
            all_keys.update(store)
        for key in (key_iter or sorted(all_keys)):
            val = self.get(key)
            if val is None:
                continue
            for nid in self.owners(key):
                if key not in self._stores[nid]:
                    self._stores[nid][key] = val
                    copies += 1
        return copies
