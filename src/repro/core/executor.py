"""Execution plane (paper §3.1 P3–P6, §3.6): interprets IR-plane sub-DAGs
with JAX as the ML engine.

* ``OP_IMPLS``: the op vocabulary — each op id maps to (init, apply).
  New ops plug in through ``register_op`` (P5/P6 task universality).
* ``SubDagExecutor``: one compnode's runtime.  FP runs the sub-DAG and
  captures a ``jax.vjp`` pullback; BP consumes cotangents arriving from
  user compnodes and emits cotangents to producer compnodes (the paper's
  BP-task message passing, reversed FP edges); Update applies a local
  optimizer to the parametric ops it hosts.
* ``LocalCluster``: wires executors together through a ``Bus`` that
  accounts every transferred byte (validating the DAG cut-size model).
* ``spmd_pipeline``: the TPU-native production mapping — the same staged
  execution as a ``shard_map`` collective_permute pipeline.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.dag import DAG, LOSS, PARAMETRIC, PLACEHOLDER
from repro.models import ssm
from repro.models.layers import (attn_apply, attn_init, embed_init, ffn_apply,
                                 ffn_init, moe_apply, moe_init, rmsnorm,
                                 rmsnorm_init)

Array = jax.Array


# ---------------------------------------------------------------------------
# Op vocabulary (the IR-plane/execution-plane contract)
# ---------------------------------------------------------------------------

def _res_block(mixer_apply):
    def apply(params, cfg, x, positions):
        h = rmsnorm(x, params["norm"], cfg.norm_eps)
        h = mixer_apply(params, cfg, h, positions)
        return x + h
    return apply


def _attn(params, cfg, h, positions, window=0):
    out, _ = attn_apply(params["op"], h, cfg, positions=positions, window=window)
    return out


def _swa(params, cfg, h, positions):
    return _attn(params, cfg, h, positions, window=cfg.sliding_window)


def _mamba(params, cfg, h, positions):
    out, _ = ssm.mamba_apply(params["op"], h, cfg)
    return out


def _rwkv(params, cfg, h, positions):
    out, _ = ssm.rwkv_apply(params["op"], h, cfg)
    return out


def _dense_ffn(params, cfg, h, positions):
    return ffn_apply(params["op"], h)


def _moe_ffn(params, cfg, h, positions):
    out, _aux = moe_apply(params["op"], h, cfg)   # aux folded by the driver
    return out


_MIXER_INITS = {
    "attn_block": attn_init, "swa_block": attn_init,
    "mamba_block": ssm.mamba_init, "rwkv_block": ssm.rwkv_init,
    "dense_ffn": ffn_init, "moe_ffn": moe_init,
}

OP_IMPLS: Dict[str, dict] = {}


def register_op(op_id: str, init: Callable, apply: Callable) -> None:
    OP_IMPLS[op_id] = {"init": init, "apply": apply}


def _block_init(mixer_init):
    def init(key, cfg):
        return {"norm": rmsnorm_init(cfg.d_model), "op": mixer_init(key, cfg)}
    return init


for _op, _fn in [("attn_block", _attn), ("swa_block", _swa),
                 ("mamba_block", _mamba), ("rwkv_block", _rwkv),
                 ("dense_ffn", _dense_ffn), ("moe_ffn", _moe_ffn)]:
    register_op(_op, _block_init(_MIXER_INITS[_op]), _res_block(_fn))

register_op(
    "embedding",
    lambda key, cfg: {"embed": embed_init(key, cfg.vocab_size, cfg.d_model)},
    lambda p, cfg, tokens, positions: jnp.take(p["embed"], tokens, axis=0))

register_op(
    "unembed",
    lambda key, cfg: {"norm": rmsnorm_init(cfg.d_model),
                      "head": embed_init(key, cfg.d_model, cfg.vocab_size)},
    lambda p, cfg, x, positions: (rmsnorm(x, p["norm"], cfg.norm_eps)
                                  @ p["head"].astype(x.dtype)).astype(jnp.float32))


def _xent(p, cfg, logits, labels, positions):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean()


register_op("cross_entropy", lambda key, cfg: {}, _xent)


# ---------------------------------------------------------------------------
# Message bus with byte accounting (the decentralized communicator stand-in)
# ---------------------------------------------------------------------------

class Bus:
    def __init__(self):
        self.mailboxes: Dict[int, Dict[str, Array]] = {}
        self.bytes_sent: Dict[Tuple[int, int], float] = {}

    def send(self, src: int, dst: int, key: str, value: Array) -> None:
        self.mailboxes.setdefault(dst, {})[key] = value
        nbytes = math.prod(value.shape) * value.dtype.itemsize
        self.bytes_sent[(src, dst)] = self.bytes_sent.get((src, dst), 0.0) + nbytes

    def recv(self, dst: int, key: str) -> Array:
        box = self.mailboxes.get(dst)
        if not box or key not in box:
            raise KeyError(
                f"Bus.recv: no message {key!r} in mailbox of dst={dst} "
                f"(available keys: {sorted(box) if box else []}) — "
                f"a DAG cut is mis-scheduled or the producer never sent")
        return box.pop(key)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_sent.values())


# ---------------------------------------------------------------------------
# Sub-DAG executor: FP / BP / Update tasks (paper §3.6)
# ---------------------------------------------------------------------------

class SubDagExecutor:
    def __init__(self, compnode_id: int, dag: DAG, op_names: Sequence[str],
                 cfg, key):
        self.compnode_id = compnode_id
        self.dag = dag
        self.op_names = list(op_names)
        self.cfg = cfg
        self.params: Dict[str, dict] = {}
        keys = jax.random.split(key, max(1, len(self.op_names)))
        for k, name in zip(keys, self.op_names):
            node = dag[name]
            if node.op_type in (PARAMETRIC, LOSS) or node.op in OP_IMPLS:
                if node.op in OP_IMPLS:
                    self.params[name] = OP_IMPLS[node.op]["init"](k, cfg)
        self._pullback = None
        self._out_keys: List[str] = []

    # -- the pure function of (params, external inputs) -> sent outputs ----
    def _fp_fn(self, params, ext_inputs: Dict[str, Array],
               placeholders: Dict[str, Array], positions,
               want: Optional[str] = None):
        values: Dict[str, Array] = dict(ext_inputs)
        values.update(placeholders)
        loss = None
        for name in self.op_names:
            node = self.dag[name]
            if node.op_type == PLACEHOLDER:
                continue
            args = [values[a] for a in node.args]
            out = OP_IMPLS[node.op]["apply"](params.get(name, {}), self.cfg,
                                             *args, positions)
            values[name] = out
            if node.op_type == LOSS:
                loss = out
        outs = {k: values[k] for k in self._out_keys}
        wanted = values.get(want) if want else None
        return outs, (loss, wanted)

    def fp(self, bus: Bus, assignment: Dict[str, int],
           placeholders: Dict[str, Array], positions,
           want: Optional[str] = None) -> Tuple[Optional[Array], Optional[Array]]:
        """FP task: pull outer-required data from the bus, execute, push
        outwards data.  Captures the vjp pullback for the BP task.
        Returns (loss, wanted-op value)."""
        my = self.compnode_id
        mine = set(self.op_names)
        ext_needed = sorted({a for n in self.op_names for a in self.dag[n].args
                             if a not in mine})
        ext_inputs = {a: bus.recv(my, f"fp/{a}") for a in ext_needed}
        self._out_keys = sorted({
            n for n in self.op_names
            if any(assignment[u] != my for u in self.dag.users(n))})

        fn = lambda p, e: self._fp_fn(p, e, placeholders, positions, want)
        (outs, (loss, wanted)), self._pullback = jax.vjp(
            fn, self.params, ext_inputs, has_aux=False)
        self._ext_keys = ext_needed
        for name, val in outs.items():
            for u in self.dag.users(name):
                dst = assignment[u]
                if dst != my:
                    bus.send(my, dst, f"fp/{name}", val)
        return loss, wanted

    def bp(self, bus: Bus, assignment: Dict[str, int],
           loss_cotangent: float = 1.0) -> Dict[str, dict]:
        """BP task: assemble cotangents for every sent output (from user
        compnodes), pull back, send cotangents for external inputs to their
        producers.  Returns parameter gradients for hosted ops."""
        my = self.compnode_id
        out_ct = {}
        for name in self._out_keys:
            ct = None
            remote_peers = {assignment[u] for u in self.dag.users(name)} - {my}
            for peer in sorted(remote_peers):
                piece = bus.recv(my, f"bp/{name}/{peer}")
                ct = piece if ct is None else ct + piece
            out_ct[name] = ct
        has_loss = any(self.dag[n].op_type == LOSS for n in self.op_names)
        loss_ct = jnp.asarray(loss_cotangent, jnp.float32) if has_loss else None
        param_grads, ext_ct = self._pullback((out_ct, (loss_ct, None)))
        for name, ct in ext_ct.items():
            src = assignment[name]
            bus.send(my, src, f"bp/{name}/{self.compnode_id}", ct)
        # route by (producer, this-consumer) key so multiple consumers sum
        return param_grads

    def update(self, grads: Dict[str, dict], lr: float = 1e-3) -> None:
        """Update task: plain SGD on hosted parametric ops (per-op
        optimizers configurable by the job file; SGD keeps the cluster
        test exact)."""
        self.params = jax.tree.map(
            lambda p, g: (p - lr * g.astype(p.dtype)).astype(p.dtype)
            if g is not None else p,
            self.params, grads)


# Wait-free ordering note: fp/bp must run in stage order in this
# single-process simulation; the cluster drives that.
class LocalCluster:
    """All compnode executors in one process, wired via a byte-accounting
    bus — the decentralized system in miniature."""

    def __init__(self, dag: DAG, parts: Sequence[Sequence[str]], cfg, key,
                 peer_ids: Optional[Sequence[int]] = None):
        self.dag = dag
        self.cfg = cfg
        self.parts = [list(p) for p in parts]
        self.peer_ids = list(peer_ids) if peer_ids else list(range(len(parts)))
        self.assignment = {n: self.peer_ids[i]
                           for i, part in enumerate(parts) for n in part}
        keys = jax.random.split(key, len(parts))
        self.executors = [SubDagExecutor(self.peer_ids[i], dag, part, cfg, keys[i])
                          for i, part in enumerate(self.parts)]
        self.bus = Bus()

    def train_step(self, tokens: Array, labels: Array, lr: float = 1e-3
                   ) -> float:
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        placeholders_all = {"input": tokens, "label": labels}
        loss = None
        for ex in self.executors:                      # FP in stage order
            ph = {n: placeholders_all[n] for n in ex.op_names
                  if self.dag[n].op_type == PLACEHOLDER}
            l, _ = ex.fp(self.bus, self.assignment, ph, positions)
            loss = l if l is not None else loss
        grads = {}
        for ex in reversed(self.executors):            # BP in reverse order
            grads[ex.compnode_id] = ex.bp(self.bus, self.assignment)
        for ex in self.executors:                      # Update
            ex.update(grads[ex.compnode_id], lr)
        return float(loss)

    def forward(self, tokens: Array, want: str = "head") -> Array:
        """Inference FP through the pipeline; returns ``want``'s output
        (logits for an unembed-terminated DAG)."""
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        out = None
        for ex in self.executors:
            ph = {n: tokens for n in ex.op_names
                  if self.dag[n].op_type == PLACEHOLDER}
            _, wanted = ex.fp(self.bus, self.assignment, ph, positions,
                              want=want)
            out = wanted if wanted is not None else out
        return out


# ---------------------------------------------------------------------------
# SPMD pipeline (shard_map + collective_permute): production mapping
# ---------------------------------------------------------------------------

def spmd_pipeline(stage_fn: Callable, stacked_params, x_microbatches: Array,
                  mesh, axis: str = "stage"):
    """Run a GPipe-style pipeline over the mesh axis ``axis``.

    stage_fn(params_i, x) -> x; ``stacked_params`` has a leading axis of
    size n_stages sharded over ``axis``; ``x_microbatches``: (n_micro, ...)
    microbatch inputs (resident on stage 0's shard conceptually; every
    stage receives its predecessor's output via collective_permute).

    Returns (n_micro, ...) outputs produced by the last stage.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_microbatches.shape[0]
    total = n_micro + n_stages - 1                     # fill + drain ticks

    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def per_stage(params, xs):
        params = jax.tree.map(lambda a: a[0], params)  # my stage's params
        xs = xs[0]                                     # (n_micro, ...) local
        idx = jax.lax.axis_index(axis)
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            state, outs = carry                        # state: current x
            mb = jnp.clip(t, 0, n_micro - 1)
            inject = xs[mb]
            # stage 0 takes fresh microbatches; others take permuted input
            x_in = jnp.where(idx == 0, inject, state)
            y = stage_fn(params, x_in)
            # pass activations forward along the chain
            state_next = jax.lax.ppermute(y, axis, perm)
            # last stage records its output at the right slot
            out_t = t - (n_stages - 1)
            write = (idx == n_stages - 1) & (out_t >= 0)
            outs = jax.lax.cond(
                write,
                lambda o: o.at[jnp.clip(out_t, 0, n_micro - 1)].set(y),
                lambda o: o,
                outs)
            return (state_next, outs), None

        y0 = jax.eval_shape(stage_fn, params, xs[0])
        outs0 = jnp.zeros((n_micro,) + y0.shape, y0.dtype)
        state0 = jnp.zeros(y0.shape, y0.dtype)
        (_, outs), _ = jax.lax.scan(tick, (state0, outs0),
                                    jnp.arange(total))
        return outs[None]

    spec_params = jax.tree.map(lambda _: P(axis), stacked_params)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(spec_params, P(axis)),
                   out_specs=P(axis), check_rep=False)
    # replicate microbatches across stages (each stage uses only what it needs)
    xs_tiled = jnp.broadcast_to(x_microbatches[None],
                                (n_stages,) + x_microbatches.shape)
    outs = fn(stacked_params, xs_tiled)
    return outs[-1]
