"""Broker (paper §3.2): bridges job submitters and compnodes.

* registry with unique IDs and basic hardware info;
* periodic ping-pong heartbeats to detect offline nodes;
* a **backup pool**: a fraction of registered providers held in reserve;
* on failure of a node with unfinished tasks, a replacement is drafted
  from the backup pool (closest speed first) and the task remapped;
* job intake: DAG -> decomposer -> scheduler -> task table.

The collaboration dynamics (joins/quits) run as a deterministic
event-driven simulation (seeded numpy RNG), which is how the paper's own
evaluation treats peer variability.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.dag import DAG
from repro.core.decomposer import decompose_contiguous
from repro.core.dht import DHT
from repro.core.perfmodel import CompNode, PerfModel
from repro.core.scheduler import Schedule, Task, schedule_loadbalance, \
    tasks_from_parts


@dataclass
class Event:
    t: float
    kind: str                  # join | quit | fail | replace | reschedule
    node_id: int
    detail: str = ""


class Broker:
    def __init__(self, *, backup_fraction: float = 0.2, seed: int = 0,
                 heartbeat_s: float = 10.0):
        self.active: Dict[int, CompNode] = {}
        self.backup: Dict[int, CompNode] = {}
        # node_id -> FLOP/s, recorded at registration and kept after the
        # node dies: replacement drafting matches the DEAD node's speed,
        # and by then the node object is already popped from the pools.
        self.speeds: Dict[int, float] = {}
        self.backup_fraction = backup_fraction
        self.heartbeat_s = heartbeat_s
        self.rng = np.random.RandomState(seed)
        # separate seeded stream for backup-pool pings: active-failure
        # outcomes for a given seed stay independent of how many
        # standbys are registered (and identical to a broker that never
        # pinged backups at all)
        self._backup_rng = np.random.RandomState((seed ^ 0x9E3779B9)
                                                 & 0xFFFFFFFF)
        self.events: List[Event] = []
        self.tasks: Dict[int, Task] = {}
        self.schedule: Optional[Schedule] = None
        self.dag: Optional[DAG] = None
        self.dht: DHT = DHT([])
        self._next_id = 0
        self._t = 0.0

    # ------------------------------------------------------------------
    # membership (P1: autonomous join/quit)
    # ------------------------------------------------------------------
    def register(self, node: CompNode, pool: str = "auto") -> int:
        """Register a provider.  ``pool`` is ``"auto"`` (keep roughly
        ``backup_fraction`` of the fleet in reserve), or an explicit
        ``"active"`` / ``"backup"`` for callers that manage their own
        replica/standby split (e.g. the serving ``FleetRouter``)."""
        if pool not in ("auto", "active", "backup"):
            raise ValueError(f"Broker.register: unknown pool {pool!r} "
                             f"(expected 'auto', 'active' or 'backup')")
        if node.node_id in self.active or node.node_id in self.backup:
            raise ValueError(
                f"Broker.register: node object already registered as "
                f"{node.node_id} — each provider needs its own CompNode")
        node.node_id = self._next_id
        self._next_id += 1
        self.speeds[node.node_id] = node.speed
        n_active = len(self.active)
        n_backup = len(self.backup)
        if pool == "backup" or (
                pool == "auto" and n_active > 0
                and n_backup < self.backup_fraction * (n_active + n_backup + 1)):
            self.backup[node.node_id] = node
            kind = "backup"
        else:
            self.active[node.node_id] = node
            self.dht.join(node.node_id)
            kind = "active"
        self.events.append(Event(self._t, "join", node.node_id, kind))
        return node.node_id

    def quit(self, node_id: int, graceful: bool = True) -> None:
        node = self.active.pop(node_id, None) or self.backup.pop(node_id, None)
        if node is None:
            return
        node.online = False
        self.dht.leave(node_id)
        if self.schedule is not None:
            # a corpse must not count toward makespan
            self.schedule.loads.pop(node_id, None)
        self.events.append(Event(self._t, "quit", node_id,
                                 "graceful" if graceful else "failure"))
        if self._unfinished_on(node_id):
            self._replace(node_id)

    # ------------------------------------------------------------------
    # job intake (decompose + schedule, §3.2 / §3.8)
    # ------------------------------------------------------------------
    def submit_job(self, dag: DAG, *, n_parts: Optional[int] = None) -> Schedule:
        self.dag = dag
        nodes = list(self.active.values())
        assert nodes, "no active compnodes"
        k = n_parts or len(nodes)
        speeds = [n.speed for n in sorted(nodes, key=lambda n: -n.speed)][:k]
        parts = decompose_contiguous(dag, k, speeds=speeds)
        tasks = tasks_from_parts(dag, parts)
        self.tasks = {t.task_id: t for t in tasks}
        self.schedule = schedule_loadbalance(tasks, nodes)
        self._done: Dict[int, bool] = {t.task_id: False for t in tasks}
        return self.schedule

    def mark_done(self, task_id: int) -> None:
        self._done[task_id] = True

    def _unfinished_on(self, node_id: int) -> List[int]:
        if not self.schedule:
            return []
        return [tid for tid, nid in self.schedule.assignment.items()
                if nid == node_id and not self._done.get(tid, False)]

    # ------------------------------------------------------------------
    # fault tolerance: heartbeat + backup-pool replacement
    # ------------------------------------------------------------------
    def activate_backup(self, node_id: int, detail: str = "") -> Optional[CompNode]:
        """Move one SPECIFIC backup into the active pool (drafted by a
        caller that chose it for its own reason, e.g. the serving router
        activating the only standby whose model can run a request)."""
        sub = self.backup.pop(node_id, None)
        if sub is None:
            return None
        self.active[sub.node_id] = sub
        self.dht.join(sub.node_id)
        self.events.append(Event(self._t, "replace", sub.node_id,
                                 detail or "drafted"))
        self.dht.rebalance()
        return sub

    def draft_backup(self, dead_id: int) -> Optional[CompNode]:
        """Draft the backup whose SPEED (FLOP/s) best matches the dead
        node's recorded speed — the drafted peer inherits the dead one's
        role, so matching on throughput keeps the schedule balanced.
        (The dead node is already popped from the pools; ``self.speeds``
        keeps its registration-time speed.)  Returns the activated node,
        or None when the backup pool is empty."""
        if not self.backup:
            return None
        dead_speed = self.speeds.get(dead_id, 1.0)
        sub_id = min(self.backup,
                     key=lambda nid: abs(self.backup[nid].speed - dead_speed))
        return self.activate_backup(sub_id, f"for {dead_id}")

    def _replace(self, dead_id: int) -> Optional[int]:
        pending = self._unfinished_on(dead_id)
        if not pending:
            return None
        sub = self.draft_backup(dead_id)
        if sub is not None:
            self.events[-1].detail += f" tasks={pending}"
            for tid in pending:
                self.schedule.assignment[tid] = sub.node_id
            self.schedule.loads[sub.node_id] = (
                self.schedule.loads.get(sub.node_id, 0.0)
                + sum(self.tasks[tid].flops / sub.speed for tid in pending))
            return sub.node_id
        # no backups left: reschedule pending tasks over surviving actives,
        # seeded with their CURRENT loads and memory footprints so the
        # rebalance sees real commitments (time and bytes), and merge the
        # result back so makespan stays truthful
        self.events.append(Event(self._t, "reschedule", dead_id,
                                 f"tasks={pending} (backup pool empty)"))
        remaining = [self.tasks[tid] for tid in pending]
        survivors = list(self.active.values())
        if not survivors:
            return None
        moving = set(pending)
        init_used = {nid: [0.0, 0.0, 0.0] for nid in self.active}
        for tid, nid in self.schedule.assignment.items():
            if nid in init_used and tid not in moving:
                t = self.tasks[tid]
                init_used[nid][0] += t.gpu_bytes
                init_used[nid][1] += t.cpu_bytes
                init_used[nid][2] += t.disk_bytes
        sched = schedule_loadbalance(remaining, survivors,
                                     init_loads=self.schedule.loads,
                                     init_used=init_used)
        for tid, nid in sched.assignment.items():
            self.schedule.assignment[tid] = nid
        self.schedule.loads.update(sched.loads)
        return None

    def heartbeat_round(self) -> List[int]:
        """Ping-pong every registered node — actives AND backups — each
        failing with (1 - reliability) per round.  Standbys are not
        immortal: a dead backup is dropped from the pool so it can never
        be drafted as a replacement.  Backups draw from their own seeded
        stream, so active-failure outcomes for a given seed are stable
        regardless of backup-pool size.  Returns the list of nodes
        detected offline (actives first)."""
        self._t += self.heartbeat_s
        dead = []
        for nid, node in list(self.active.items()):
            if self.rng.random_sample() > node.reliability:
                dead.append(nid)
        for nid, node in list(self.backup.items()):
            if self._backup_rng.random_sample() > node.reliability:
                dead.append(nid)
        for nid in dead:
            self.quit(nid, graceful=False)
        return dead

    def run_sim(self, rounds: int) -> dict:
        """Run heartbeat rounds until tasks complete or fleet dies.
        Task completion is modeled by load-proportional progress."""
        failures = 0
        for _ in range(rounds):
            failures += len(self.heartbeat_round())
            if not self.active:
                break
        return {
            "rounds": rounds,
            "failures": failures,
            "replacements": sum(1 for e in self.events if e.kind == "replace"),
            "reschedules": sum(1 for e in self.events if e.kind == "reschedule"),
            "active": len(self.active),
            "backup": len(self.backup),
            "all_tasks_assigned": self.schedule is None or all(
                nid in self.active
                for tid, nid in self.schedule.assignment.items()
                if not self._done.get(tid, False)),
        }
