"""Generic decoder model: builds any assigned architecture from its
``ModelConfig`` (dense / MoE / SSM / hybrid / VLM / audio backbones).

Depth is organised as ``prefix_layers`` (unrolled) + one scanned stack of
repeating periods (``cfg.stacks``), so a 126-layer model lowers to HLO the
size of one period.  Pre-norm residual blocks:

    x = x + mixer(norm1(x));  x = x + ffn(norm2(x))

Decode caches are pytrees mirroring the layer structure; stack caches have
a leading ``n_periods`` axis and are scanned together with the stacked
parameters.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import mla, ssm
from repro.models.hints import constrain
from repro.models.config import ATTN, DENSE, MAMBA, MOE, RWKV, SWA, ModelConfig
from repro.models.layers import (attn_apply, attn_init, cache_init, dense_init,
                                 embed_init, ffn_apply, ffn_init, moe_apply,
                                 moe_init, paged_cache_init, rmsnorm,
                                 rmsnorm_init)

Array = jax.Array


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------

def layer_init(key, spec, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"norm1": rmsnorm_init(cfg.d_model), "norm2": rmsnorm_init(cfg.d_model)}
    if spec.mixer in (ATTN, SWA):
        p["mixer"] = mla.mla_init(k1, cfg) if cfg.use_mla else attn_init(k1, cfg)
    elif spec.mixer == MAMBA:
        p["mixer"] = ssm.mamba_init(k1, cfg)
    elif spec.mixer == RWKV:
        p["mixer"] = ssm.rwkv_init(k1, cfg)
    p["ffn"] = moe_init(k2, cfg) if spec.ffn == MOE else ffn_init(k2, cfg)
    return p


def layer_cache_init(spec, cfg: ModelConfig, batch: int, cache_len: int,
                     dtype=jnp.bfloat16, *, paged: bool = False,
                     page_size: int = 16, num_blocks: int = 0,
                     num_blocks_swa: Optional[int] = None):
    """Dense per-slot cache, or (``paged=True``) a shared block pool of
    ``num_blocks`` pages per attention-family layer.  SSM/RWKV state is
    per-slot either way (a recurrent carry has no sequence axis to page).

    ``num_blocks_swa``: sliding-window layers cycle over at most
    ``ceil(window / page_size)`` ring pages per slot, so their pools live
    in a separate, much smaller block-id space (the engine's dedicated
    SWA allocator/table) instead of full-attention-sized pools.  Defaults
    to ``num_blocks`` (single shared id space) for direct callers."""
    if paged and spec.mixer in (ATTN, SWA):
        if spec.mixer == SWA:
            num_blocks = (num_blocks_swa if num_blocks_swa is not None
                          else num_blocks)
        if cfg.use_mla:
            return mla.mla_paged_cache_init(num_blocks, page_size, cfg, dtype)
        return paged_cache_init(num_blocks, page_size, cfg.n_kv_heads,
                                cfg.head_dim, dtype)
    if spec.mixer == ATTN:
        if cfg.use_mla:
            return mla.mla_cache_init(batch, cache_len, cfg, dtype)
        return cache_init(batch, cache_len, cfg.n_kv_heads, cfg.head_dim, dtype)
    if spec.mixer == SWA:
        ring = min(cfg.sliding_window, cache_len)
        return cache_init(batch, ring, cfg.n_kv_heads, cfg.head_dim, dtype)
    if spec.mixer == MAMBA:
        return ssm.mamba_cache_init(batch, cfg, dtype)
    if spec.mixer == RWKV:
        return ssm.rwkv_cache_init(batch, cfg, dtype)
    raise ValueError(spec.mixer)


def layer_apply(lp: dict, spec, cfg: ModelConfig, x: Array, positions: Array,
                cache: Optional[dict], *, decode: bool = False,
                kv_chunk: int = 1024, masked_slots: bool = False,
                block_table: Optional[Array] = None,
                use_kernel: bool = False):
    """Returns (x, new_cache, aux_loss).

    ``masked_slots``: batch rows whose positions are all < 0 (idle serving
    slots) keep their previous cache/state verbatim — required by the
    continuous batcher, skipped on hot paths to avoid extra cache traffic.
    Attention-family caches get this entry-wise from the per-row masked
    ring write (valid for multi-token chunked prefill against a populated
    cache); SSM/RWKV recurrent states are restored row-wise after the scan.

    ``block_table``: (B, n_cols) int32 page table for paged caches —
    consumed by the attention-family mixers only; recurrent state is
    per-slot and ignores it.

    ``use_kernel``: paged single-token decode runs the fused Pallas
    paged-attention kernel instead of the chunked-gather scan path
    (attention-family mixers only; a no-op for every other shape).
    """
    x = constrain(x, "residual")
    h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
    if spec.mixer in (ATTN, SWA):
        window = cfg.sliding_window if spec.mixer == SWA else 0
        if cfg.use_mla:
            h, new_cache = mla.mla_apply(lp["mixer"], h, cfg, positions=positions,
                                         cache=cache, decode=decode,
                                         kv_chunk=kv_chunk,
                                         masked_slots=masked_slots,
                                         table=block_table,
                                         use_kernel=use_kernel)
        else:
            h, new_cache = attn_apply(lp["mixer"], h, cfg, positions=positions,
                                      cache=cache, window=window,
                                      kv_chunk=kv_chunk,
                                      masked_slots=masked_slots,
                                      table=block_table,
                                      use_kernel=use_kernel)
    elif spec.mixer == MAMBA:
        h, new_cache = ssm.mamba_apply(lp["mixer"], h, cfg, cache=cache)
    elif spec.mixer == RWKV:
        h, new_cache = ssm.rwkv_apply(lp["mixer"], h, cfg, cache=cache)
    else:
        raise ValueError(spec.mixer)
    if (masked_slots and cache is not None and new_cache is not None
            and spec.mixer in (MAMBA, RWKV)):
        # recurrent states are scan carries, not position-addressed writes:
        # rows whose positions are all < 0 ran the scan on padding — put
        # their previous state back wholesale
        valid = (positions >= 0).any(axis=1)
        new_cache = jax.tree.map(
            lambda n, o: jnp.where(
                valid.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
            new_cache, cache)
    x = x + h

    h = rmsnorm(x, lp["norm2"], cfg.norm_eps)
    if spec.ffn == MOE:
        h, aux = moe_apply(lp["ffn"], h, cfg)
    else:
        h, aux = ffn_apply(lp["ffn"], h), jnp.zeros((), jnp.float32)
    return x + h, new_cache, aux


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def init_params(rng, cfg: ModelConfig) -> dict:
    keys = jax.random.split(rng, 6)
    params = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model),
              "final_norm": rmsnorm_init(cfg.d_model)}
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size)
    if cfg.ext_embed_dim:
        params["ext_proj"] = dense_init(keys[2], cfg.ext_embed_dim, cfg.d_model)

    if cfg.prefix_layers:
        pks = jax.random.split(keys[3], len(cfg.prefix_layers))
        params["prefix"] = tuple(
            layer_init(pk, spec, cfg) for pk, spec in zip(pks, cfg.prefix_layers))

    for stack in cfg.stacks:
        def period_init(k):
            lks = jax.random.split(k, len(stack.period))
            return tuple(layer_init(lk, spec, cfg)
                         for lk, spec in zip(lks, stack.period))
        params["stack"] = jax.vmap(period_init)(
            jax.random.split(keys[4], stack.n_periods))

    if cfg.mtp_depth:
        mk = jax.random.split(keys[5], 3)
        params["mtp"] = {
            "norm_h": rmsnorm_init(cfg.d_model),
            "norm_e": rmsnorm_init(cfg.d_model),
            "proj": dense_init(mk[0], 2 * cfg.d_model, cfg.d_model),
            "layer": layer_init(mk[1], cfg.period[-1], cfg),
        }
    return params


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16, *, paged: bool = False,
               page_size: int = 16, num_blocks: Optional[int] = None,
               num_blocks_swa: Optional[int] = None) -> dict:
    """Decode-cache pytree.  ``paged=True`` replaces the dense per-slot
    (batch, cache_len, ...) attention caches with per-layer block pools of
    ``num_blocks`` pages (default: the same total memory as the dense
    cache, ceil(batch * cache_len / page_size) blocks) addressed through a
    host-managed block table — see ``repro.serve.engine.ServingEngine``.
    ``num_blocks_swa`` sizes sliding-window layer pools separately
    (``ceil(window/page)`` ring pages per slot suffice); None keeps one
    shared id space."""
    if num_blocks is None:
        num_blocks = max(1, -(-batch * cache_len // page_size))
    kw = dict(paged=paged, page_size=page_size, num_blocks=num_blocks,
              num_blocks_swa=num_blocks_swa)
    caches = {}
    if cfg.prefix_layers:
        caches["prefix"] = tuple(
            layer_cache_init(spec, cfg, batch, cache_len, dtype, **kw)
            for spec in cfg.prefix_layers)
    for stack in cfg.stacks:
        one = tuple(layer_cache_init(spec, cfg, batch, cache_len, dtype, **kw)
                    for spec in stack.period)
        caches["stack"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (stack.n_periods,) + a.shape),
            one)
    return caches


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def embed_inputs(params: dict, cfg: ModelConfig, batch: dict) -> Array:
    """batch: {"tokens": (B,S) int32} and/or {"embeds": (B,S,ext_dim)}."""
    if "embeds" in batch:   # vlm/audio frontend stub output
        x = batch["embeds"].astype(jnp.bfloat16) @ params["ext_proj"].astype(
            jnp.bfloat16)
        if "tokens" in batch:  # mixed modality: add token embeddings
            x = x + jnp.take(params["embed"], batch["tokens"], axis=0)
        return x
    return jnp.take(params["embed"], batch["tokens"], axis=0)


def unembed(params: dict, cfg: ModelConfig, h: Array) -> Array:
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return (h @ head.astype(h.dtype)).astype(jnp.float32)


def _remat_wrap(body, remat, remat_policy):
    if not remat:
        return body
    policy = None
    if remat_policy == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
    elif remat_policy == "dots_no_batch":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(body, policy=policy)


def forward(params: dict, cfg: ModelConfig, batch: dict, *,
            caches: Optional[dict] = None, positions: Optional[Array] = None,
            decode: bool = False, remat: bool = False, kv_chunk: int = 1024,
            compute_logits: bool = True, masked_slots: bool = False,
            remat_policy: str = "full", block_table: Optional[Array] = None,
            use_kernel: bool = False):
    """Run the decoder.

    Returns (logits_or_hidden, aux_loss, new_caches).  ``positions``
    defaults to arange(S) broadcast over batch.  ``decode=True`` selects
    single-token cache paths (absorbed MLA etc.).  ``block_table`` marks
    ``caches`` as paged pools (see ``init_cache(paged=True)``) and routes
    every attention-family cache access through the page table;
    ``use_kernel=True`` additionally runs paged single-token decode
    attention through the fused Pallas kernel.
    """
    x = embed_inputs(params, cfg, batch)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    aux = jnp.zeros((), jnp.float32)
    new_caches = {} if caches is not None else None

    for i, spec in enumerate(cfg.prefix_layers):
        c = caches["prefix"][i] if caches is not None else None
        x, nc, a = layer_apply(params["prefix"][i], spec, cfg, x, positions, c,
                               decode=decode, kv_chunk=kv_chunk,
                               masked_slots=masked_slots,
                               block_table=block_table,
                               use_kernel=use_kernel)
        aux += a
        if caches is not None:
            new_caches.setdefault("prefix", []).append(nc)
    if caches is not None and cfg.prefix_layers:
        new_caches["prefix"] = tuple(new_caches["prefix"])

    for stack in cfg.stacks:
        def period_apply(x, pp, pc):
            a_tot = jnp.zeros((), jnp.float32)
            ncs = []
            for j, spec in enumerate(stack.period):
                x, nc, a = layer_apply(pp[j], spec, cfg, x, positions,
                                       pc[j] if pc is not None else None,
                                       decode=decode, kv_chunk=kv_chunk,
                                       masked_slots=masked_slots,
                                       block_table=block_table,
                                       use_kernel=use_kernel)
                ncs.append(nc)
                a_tot += a
            return x, tuple(ncs), a_tot

        if caches is not None:
            def body(carry, xs):
                x, a = carry
                pp, pc = xs
                x, ncs, da = period_apply(x, pp, pc)
                return (x, a + da), ncs
            body = _remat_wrap(body, remat, remat_policy)
            (x, aux), stack_caches = jax.lax.scan(
                body, (x, aux), (params["stack"], caches["stack"]))
            new_caches["stack"] = stack_caches
        else:
            def body(carry, pp):
                x, a = carry
                x, _, da = period_apply(x, pp, None)
                return (x, a + da), None
            body = _remat_wrap(body, remat, remat_policy)
            (x, aux), _ = jax.lax.scan(body, (x, aux), params["stack"])

    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    out = unembed(params, cfg, h) if compute_logits else h
    return out, aux, new_caches


# ---------------------------------------------------------------------------
# Multi-token prediction head (DeepSeek-V3 MTP, depth 1)
# ---------------------------------------------------------------------------

def mtp_hidden(params: dict, cfg: ModelConfig, h: Array, next_tokens: Array,
               positions: Array):
    """DeepSeek-style MTP module: predict token t+2 from hidden h_t fused
    with the embedding of token t+1.

    h: (B,S,d) final hidden (pre-head); next_tokens: (B,S) = token t+1.
    Returns (hidden for the shared head, aux).
    """
    mp = params["mtp"]
    e = jnp.take(params["embed"], next_tokens, axis=0)
    z = jnp.concatenate([rmsnorm(h, mp["norm_h"], cfg.norm_eps),
                         rmsnorm(e, mp["norm_e"], cfg.norm_eps)], axis=-1)
    z = z @ mp["proj"].astype(z.dtype)
    z, _, a = layer_apply(mp["layer"], cfg.period[-1], cfg, z, positions, None)
    return rmsnorm(z, params["final_norm"], cfg.norm_eps), a


def mtp_logits(params: dict, cfg: ModelConfig, h: Array, next_tokens: Array,
               positions: Array):
    hN, a = mtp_hidden(params, cfg, h, next_tokens, positions)
    return unembed(params, cfg, hN), a
