"""State-space / linear-recurrence mixers: Mamba (selective scan) and
RWKV6 "Finch" (data-dependent decay).

Both are written as chunked sequential scans: an outer ``lax.scan`` over
chunks with a rematerialized inner ``lax.scan`` over time steps, so the
(B, d_inner, d_state) hidden states are never materialized over the full
sequence — only chunk-boundary carries are saved for the backward pass.
The per-chunk bodies are the compute hot spots mirrored by the Pallas
``ssm_scan`` kernel in ``repro.kernels``.

Serving-cache note: these mixers carry a fixed-size recurrent state per
batch row — there is no sequence axis to page, so the paged slot cache
(block-table KV pools, see ``repro.models.layers``/``serve.engine``)
leaves SSM/RWKV state per-slot.  The engine's slot reset clears it
row-wise, and ``layer_apply``'s masked-slot restore puts the previous
carry back for rows whose positions are all -1 (idle slots ran the scan
on padding).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm, rmsnorm_init

Array = jax.Array

SCAN_CHUNK = 128


def _chunked_scan(step_fn, h0, xs, length: int, chunk: int = SCAN_CHUNK):
    """Outer scan over chunks with rematerialized inner scan over steps.

    xs: pytree of (S, ...) arrays (time-major). Returns (h_final, ys)
    with ys time-major (S, ...).

    Padded tail steps (length not a multiple of the chunk) are state
    no-ops: a decay/transition step on zero-padding is NOT the identity
    (RWKV decays by w(0), Mamba by exp(dt(0)·A)), so without gating the
    returned carry would be corrupted for any cached prefill with
    length > chunk and length % chunk != 0.
    """
    c = min(chunk, length)
    n_chunks = -(-length // c)
    pad = n_chunks * c - length
    if pad:
        xs = jax.tree.map(lambda a: jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1)), xs)
        valid = jnp.arange(n_chunks * c) < length
        inner = step_fn

        def step_fn(h, xs_v):  # noqa: F811 — gated wrapper over the step
            xs_t, v = xs_v
            h2, y = inner(h, xs_t)
            return jax.tree.map(lambda a, b: jnp.where(v, a, b), h2, h), y
        xs = (xs, valid)
    xs = jax.tree.map(lambda a: a.reshape((n_chunks, c) + a.shape[1:]), xs)

    @jax.checkpoint
    def chunk_body(h, xs_c):
        return jax.lax.scan(step_fn, h, xs_c)

    h, ys = jax.lax.scan(chunk_body, h0, xs)
    ys = jax.tree.map(lambda a: a.reshape((n_chunks * c,) + a.shape[2:])[:length], ys)
    return h, ys


# ===========================================================================
# Mamba
# ===========================================================================

def mamba_init(key, cfg) -> dict:
    d, di, ds = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state
    dtr, dc = cfg.mamba_dt_rank, cfg.mamba_d_conv
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt_bias = jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
        ks[5], (di,), jnp.float32,
        math.log(1e-3), math.log(1e-1)))))  # inverse-softplus of U[1e-3,1e-1]
    return {
        "in_proj": dense_init(ks[0], d, 2 * di),
        "conv_w": (jax.random.normal(ks[1], (dc, di), jnp.float32)
                   / math.sqrt(dc)).astype(jnp.bfloat16),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(ks[2], di, dtr + 2 * ds),
        "dt_proj": dense_init(ks[3], dtr, di, scale=dtr ** 0.5),
        "dt_bias": dt_bias,
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }


def _causal_conv(x: Array, w: Array, b: Array, prev: Optional[Array] = None):
    """Depthwise causal conv over time.  x: (B,S,di), w: (K,di).
    prev: (B,K-1,di) history for decode. Returns (y, new_history)."""
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)                      # (B,S+K-1,di)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(K))
    y = y + b.astype(x.dtype)
    return y, xp[:, -(K - 1):, :]


def _mamba_step(h, xs_t, A):
    """One selective-scan step. h: (B,di,ds) f32.
    xs_t = (x, dt, Bm, Cm): (B,di),(B,di),(B,ds),(B,ds)."""
    x_t, dt_t, B_t, C_t = xs_t
    x_t, dt_t = x_t.astype(jnp.float32), dt_t.astype(jnp.float32)
    B_t, C_t = B_t.astype(jnp.float32), C_t.astype(jnp.float32)
    dA = jnp.exp(dt_t[..., None] * A)                            # (B,di,ds)
    h = dA * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, C_t)
    return h, y


def mamba_apply(p: dict, x: Array, cfg, *, cache: Optional[dict] = None):
    """Mamba block. x: (B,S,d) -> (out, new_cache).
    cache (decode): {"h": (B,di,ds) f32, "conv": (B,K-1,di)}."""
    B, S, d = x.shape
    di, ds = cfg.mamba_d_inner, cfg.mamba_d_state
    dtr = cfg.mamba_dt_rank
    xz = x @ p["in_proj"].astype(x.dtype)
    xs, z = jnp.split(xz, 2, axis=-1)                            # (B,S,di)
    conv_prev = cache["conv"] if cache is not None else None
    xs, conv_new = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_prev)
    xs = jax.nn.silu(xs)

    proj = xs @ p["x_proj"].astype(x.dtype)                      # (B,S,dtr+2ds)
    dt, Bm, Cm = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(x.dtype)
                         + p["dt_bias"].astype(x.dtype))         # (B,S,di)
    A = -jnp.exp(p["A_log"])                                     # (di,ds) f32

    h0 = cache["h"] if cache is not None else jnp.zeros((B, di, ds), jnp.float32)
    step = lambda h, xs_t: _mamba_step(h, xs_t, A)
    if S == 1:
        h, y = step(h0, (xs[:, 0], dt[:, 0], Bm[:, 0], Cm[:, 0]))
        y = y[:, None, :]
    else:
        tm = lambda a: jnp.moveaxis(a, 1, 0)                     # time-major
        h, y = _chunked_scan(step, h0, (tm(xs), tm(dt), tm(Bm), tm(Cm)), S)
        y = jnp.moveaxis(y, 0, 1)
    y = y.astype(x.dtype) + xs * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    from repro.models.layers import row_dot
    out = row_dot(y, p["out_proj"])
    new_cache = None
    if cache is not None:
        from repro.models.hints import constrain
        new_cache = {"h": constrain(h, "cache/h"),
                     "conv": constrain(conv_new, "cache/conv")}
    return out, new_cache


def mamba_cache_init(batch: int, cfg, dtype=jnp.bfloat16) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.mamba_d_inner, cfg.mamba_d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.mamba_d_inner), dtype),
    }


# ===========================================================================
# RWKV6 (Finch)
# ===========================================================================

def rwkv_init(key, cfg) -> dict:
    d, hd, lora = cfg.d_model, cfg.rwkv_head_dim, cfg.rwkv_decay_lora
    H = cfg.rwkv_n_heads
    ks = jax.random.split(key, 9)
    return {
        "mu": jax.random.uniform(ks[0], (5, d), jnp.float32),    # shift mix r,k,v,g,w
        "wr": dense_init(ks[1], d, d),
        "wk": dense_init(ks[2], d, d),
        "wv": dense_init(ks[3], d, d),
        "wg": dense_init(ks[4], d, d),
        "wo": dense_init(ks[5], d, d, scale=1.0 / math.sqrt(2 * cfg.n_layers)),
        "w0": jnp.zeros((d,), jnp.float32) - 0.5,                # decay base
        "wA": dense_init(ks[6], d, lora),
        "wB": dense_init(ks[7], lora, d),
        "u": (jax.random.normal(ks[8], (H, hd), jnp.float32) * 0.1),  # bonus
        "ln_x": rmsnorm_init(hd),
    }


def _rwkv_step(S, xs_t, u):
    """S: (B,H,hd,hd) f32 [k-index, v-index].
    xs_t = (r,k,v,w): each (B,H,hd); u: (1,H,hd) bonus (closed over)."""
    r, k, v, w = xs_t
    r = r.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    kv = k[..., :, None] * v[..., None, :]                       # (B,H,hd,hd)
    o = jnp.einsum("bhi,bhij->bhj", r, S + u[..., None] * kv)    # (B,H,hd)
    S = w[..., :, None] * S + kv
    return S, o


def rwkv_apply(p: dict, x: Array, cfg, *, cache: Optional[dict] = None):
    """RWKV6 time-mix. x: (B,S,d) -> (out, new_cache).
    cache (decode): {"state": (B,H,hd,hd) f32, "shift": (B,d)}."""
    B, S, d = x.shape
    H, hd = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    prev = cache["shift"][:, None, :] if cache is not None else jnp.zeros(
        (B, 1, d), x.dtype)
    xx = jnp.concatenate([prev, x[:, :-1, :]], axis=1)           # shifted
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xg, xw = (x + (xx - x) * mu[i] for i in range(5))

    r = (xr @ p["wr"].astype(x.dtype)).reshape(B, S, H, hd)
    k = (xk @ p["wk"].astype(x.dtype)).reshape(B, S, H, hd)
    v = (xv @ p["wv"].astype(x.dtype)).reshape(B, S, H, hd)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))
    # data-dependent decay (the Finch contribution)
    w_dd = p["w0"].astype(jnp.float32) + (
        jnp.tanh(xw @ p["wA"].astype(x.dtype)) @ p["wB"].astype(x.dtype)
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_dd)).reshape(B, S, H, hd)             # in (0,1)

    u = p["u"][None].astype(jnp.float32)                         # (1,H,hd)
    S0 = cache["state"] if cache is not None else jnp.zeros(
        (B, H, hd, hd), jnp.float32)
    step = lambda S_, xs_t: _rwkv_step(S_, xs_t, u)
    if S == 1:
        S1, o = step(S0, (r[:, 0], k[:, 0], v[:, 0], w[:, 0]))
        o = o[:, None]
    else:
        tm = lambda a: jnp.moveaxis(a, 1, 0)
        S1, o = _chunked_scan(step, S0, (tm(r), tm(k), tm(v), tm(w)), S)
        o = jnp.moveaxis(o, 0, 1)                                # (B,S,H,hd)
    o = rmsnorm(o, p["ln_x"], cfg.norm_eps).astype(x.dtype)
    from repro.models.layers import row_dot
    out = row_dot(o.reshape(B, S, d) * g, p["wo"])
    new_cache = None
    if cache is not None:
        from repro.models.hints import constrain
        new_cache = {"state": constrain(S1, "cache/state"),
                     "shift": constrain(x[:, -1, :], "cache/shift")}
    return out, new_cache


def rwkv_cache_init(batch: int, cfg, dtype=jnp.bfloat16) -> dict:
    H, hd = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    return {
        "state": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "shift": jnp.zeros((batch, cfg.d_model), dtype),
    }
