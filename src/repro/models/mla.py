"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Two execution paths over a *latent* KV cache (rank ``kv_lora_rank`` plus a
shared ``qk_rope_dim`` rope key):

* train/prefill: decompress the latent into per-head K/V and run normal
  blocked attention (cheap when S tokens are processed at once);
* decode: **matrix-absorbed** attention — queries are pushed through the
  K up-projection so scores are taken directly against the latent cache,
  and the attention output stays in latent space until the V up-projection.
  Per-token decode therefore reads only ``kv_lora_rank + qk_rope_dim``
  numbers per cached position instead of ``n_heads * (qk_dim + v_dim)``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.hints import constrain
from repro.models.layers import attention, dense_init, rmsnorm, rmsnorm_init, rope

Array = jax.Array


def mla_init(key, cfg) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], d, qr),
        "q_norm": rmsnorm_init(qr),
        "wq_b": dense_init(ks[1], qr, H * (dn + dr)),
        "wkv_a": dense_init(ks[2], d, kr + dr),
        "kv_norm": rmsnorm_init(kr),
        "wk_b": dense_init(ks[3], kr, H * dn),
        "wv_b": dense_init(ks[4], kr, H * dv),
        "wo": dense_init(ks[5], H * dv, d, scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }


def mla_cache_init(batch: int, cache_len: int, cfg, dtype=jnp.bfloat16) -> dict:
    return {
        "ckv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dtype),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def mla_paged_cache_init(num_blocks: int, page_size: int, cfg,
                         dtype=jnp.bfloat16) -> dict:
    """Paged latent pool: block-table-addressed pages of the compressed
    (kv_lora_rank + qk_rope_dim) latent cache."""
    return {
        "ckv": jnp.zeros((num_blocks, page_size, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((num_blocks, page_size, cfg.qk_rope_dim), dtype),
        "pos": jnp.full((num_blocks, page_size), -1, jnp.int32),
    }


def _project_q(p, x, cfg, positions):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    q = rmsnorm(x @ p["wq_a"].astype(x.dtype), p["q_norm"], cfg.norm_eps)
    q = (q @ p["wq_b"].astype(x.dtype)).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(p, x, cfg, positions):
    kr, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    kv = x @ p["wkv_a"].astype(x.dtype)                          # (B,S,kr+dr)
    ckv = rmsnorm(kv[..., :kr], p["kv_norm"], cfg.norm_eps)
    krope = rope(kv[..., None, kr:], positions, cfg.rope_theta)[..., 0, :]
    return ckv, krope


def mla_apply(p: dict, x: Array, cfg, *, positions: Array,
              cache: Optional[dict] = None, decode: bool = False,
              kv_chunk: int = 1024, masked_slots: bool = False,
              table: Optional[Array] = None, use_kernel: bool = False):
    """MLA block.  Returns (out, new_cache).  ``masked_slots=True``
    selects the per-row masked cache write (continuous-batching chunked
    prefill: rows with position -1 are write no-ops).  When a (B, n_cols)
    block ``table`` is given the cache is a paged latent pool: writes
    scatter through the table; the absorbed decode path attends the pool
    page-wise, the naive prefill path gathers the dense latent view
    (it decompresses the whole cache anyway).  ``use_kernel=True`` runs
    paged absorbed decode through the fused Pallas paged-attention
    kernel — the latent pool is both K and V, the rope pool enters as
    the second score contraction, all walked page-wise via the
    scalar-prefetched block table."""
    B, S, d = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kr = cfg.kv_lora_rank
    scale = (dn + dr) ** -0.5

    q_nope, q_rope = _project_q(p, x, cfg, positions)
    ckv, krope = _project_kv_latent(p, x, cfg, positions)

    new_cache = None
    attn_table = None
    if isinstance(table, dict):
        # per-cache-kind block tables (see attn_apply): MLA layers are
        # full-attention kind and read the "attn" table
        table = table["attn"]
    if cache is not None and table is not None:
        from repro.models.layers import gather_pages, gather_pos, ring_write
        new_cache = {
            "ckv": ring_write(cache["ckv"], ckv, positions, kind="ckv",
                              table=table),
            "krope": ring_write(cache["krope"], krope, positions,
                                kind="krope", table=table),
            "pos": ring_write(cache["pos"], positions, positions,
                              kind="pos", table=table),
        }
        if decode:
            # pool-shaped latents flow straight into the paged attention
            ckv_all, krope_all, kv_pos = (new_cache["ckv"],
                                          new_cache["krope"],
                                          new_cache["pos"])
            attn_table = table
        else:
            ckv_all = gather_pages(new_cache["ckv"], table)
            krope_all = gather_pages(new_cache["krope"], table)
            kv_pos = gather_pos(new_cache["pos"], table)
    elif cache is not None:
        from repro.models.layers import ring_write
        new_cache = {
            "ckv": ring_write(cache["ckv"], ckv, positions, kind="ckv",
                              per_row=masked_slots),
            "krope": ring_write(cache["krope"], krope, positions,
                                kind="krope", per_row=masked_slots),
            "pos": ring_write(cache["pos"], positions, positions,
                              kind="pos", per_row=masked_slots),
        }
        ckv_all, krope_all, kv_pos = (new_cache["ckv"], new_cache["krope"],
                                      new_cache["pos"])
    else:
        ckv_all, krope_all, kv_pos = ckv, krope, positions

    if decode:
        # --- absorbed path: score against the latent directly.  The rope
        # term enters as a second contraction (q_extra/k_extra) so the
        # latent and rope caches never get concatenated — they carry
        # different shardings on the mesh. ---------------------------------
        wk_b = p["wk_b"].astype(x.dtype).reshape(kr, H, dn)
        q_lat = jnp.einsum("bshd,khd->bshk", q_nope, wk_b)       # (B,S,H,kr)
        # align the absorbed queries' latent/rope dims with the cache
        # sharding (kr and dr live on the model axis during decode)
        q_lat = constrain(q_lat, "attn_q")
        q_rope_c = constrain(q_rope, "attn_q")
        v_lat = ckv_all[:, :, None, :]       # (B,T,1,kr) / pool (N,P,1,kr)
        o_lat = attention(q_lat, v_lat, v_lat, positions, kv_pos,
                          scale=scale, kv_chunk=kv_chunk,
                          q_extra=q_rope_c,
                          k_extra=krope_all[:, :, None, :],
                          table=attn_table,
                          use_kernel=use_kernel)                 # (B,S,H,kr)
        wv_b = p["wv_b"].astype(x.dtype).reshape(kr, H, dv)
        o = jnp.einsum("bshk,khd->bshd", o_lat, wv_b)
    else:
        # --- naive path: decompress K/V per head -------------------------
        T = ckv_all.shape[1]
        k_nope = (ckv_all @ p["wk_b"].astype(x.dtype)).reshape(B, T, H, dn)
        v = (ckv_all @ p["wv_b"].astype(x.dtype)).reshape(B, T, H, dv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope_all[:, :, None, :], (B, T, H, dr))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = attention(q, k, v, positions, kv_pos, scale=scale, kv_chunk=kv_chunk)

    out = o.reshape(B, S, H * dv) @ p["wo"].astype(x.dtype)
    return out, new_cache
