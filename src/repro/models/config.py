"""Model configuration for the repro model zoo.

A single ``ModelConfig`` describes every architecture in the assigned pool
(dense / MoE / SSM / hybrid / VLM / audio backbones).  Layers are grouped
into *stacks* of identical repeating periods so the forward pass can
``lax.scan`` over periods and keep the lowered HLO small even for
126-layer models.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------

# mixer kinds
ATTN = "attn"          # full causal attention
SWA = "swa"            # sliding-window causal attention
MAMBA = "mamba"        # Mamba selective-scan block
RWKV = "rwkv"          # RWKV6 time-mix block

# ffn kinds
DENSE = "dense"
MOE = "moe"


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside a repeating period."""
    mixer: str = ATTN          # attn | swa | mamba | rwkv
    ffn: str = DENSE           # dense | moe

    def __post_init__(self):
        assert self.mixer in (ATTN, SWA, MAMBA, RWKV), self.mixer
        assert self.ffn in (DENSE, MOE), self.ffn


@dataclass(frozen=True)
class StackSpec:
    """``n_periods`` repetitions of the layer tuple ``period``.

    The forward pass scans over the period axis; layers inside one period
    are unrolled (they may be heterogeneous, e.g. Jamba's 1 attn + 7 mamba).
    """
    period: Tuple[LayerSpec, ...]
    n_periods: int

    @property
    def n_layers(self) -> int:
        return len(self.period) * self.n_periods


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                     # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int                       # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // n_heads

    # --- attention options -------------------------------------------------
    attn_bias: bool = False            # QKV bias (qwen1.5)
    qk_norm: bool = False              # RMSNorm on q,k per head (qwen3)
    rope_theta: float = 10_000.0
    sliding_window: int = 0            # window size for SWA layers
    logits_softcap: float = 0.0        # tanh soft-capping (gemma-style), 0=off

    # --- layer pattern ------------------------------------------------------
    # Repeating period of LayerSpecs; replicated over the depth.  Prefix
    # layers (e.g. DeepSeek's first-3-dense) are expressed via
    # ``prefix_layers`` which are unrolled before the scanned stacks.
    period: Tuple[LayerSpec, ...] = (LayerSpec(),)
    prefix_layers: Tuple[LayerSpec, ...] = ()

    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0                  # per-expert ffn width (0 -> d_ff)
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # --- Mamba (jamba) -------------------------------------------------------
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0             # 0 -> ceil(d_model/16)

    # --- RWKV6 ---------------------------------------------------------------
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64

    # --- MLA (deepseek) ------------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- MTP (deepseek multi-token prediction) -------------------------------
    mtp_depth: int = 0                 # number of extra future-token modules

    # --- modality frontends (stubs) ------------------------------------------
    # vlm/audio: inputs arrive as precomputed embeddings of shape
    # (batch, seq, ext_embed_dim); a learned projector maps to d_model.
    ext_embed_dim: int = 0

    # --- misc ----------------------------------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    source: str = ""                   # citation for the config

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.d_expert == 0 and self.n_experts:
            object.__setattr__(self, "d_expert", self.d_ff)
        if self.mamba_dt_rank == 0:
            object.__setattr__(self, "mamba_dt_rank", max(1, math.ceil(self.d_model / 16)))
        n_pattern = len(self.prefix_layers) + len(self.period) * max(
            0, (self.n_layers - len(self.prefix_layers)) // max(1, len(self.period)))
        assert n_pattern == self.n_layers, (
            f"{self.name}: n_layers={self.n_layers} not covered by "
            f"prefix({len(self.prefix_layers)}) + k*period({len(self.period)})")

    # ------------------------------------------------------------------
    @property
    def stacks(self) -> Tuple[StackSpec, ...]:
        """Scanned stacks after the unrolled prefix."""
        n_rest = self.n_layers - len(self.prefix_layers)
        n_periods = n_rest // len(self.period)
        return (StackSpec(self.period, n_periods),) if n_periods else ()

    @property
    def is_attention_free(self) -> bool:
        layers = self.prefix_layers + self.period
        return all(l.mixer in (MAMBA, RWKV) for l in layers)

    @property
    def supports_long_decode(self) -> bool:
        """True when decode memory/compute is sub-quadratic in context:
        SSM / hybrid / sliding-window archs."""
        layers = self.prefix_layers + self.period
        return any(l.mixer in (MAMBA, RWKV, SWA) for l in layers)

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def rwkv_n_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    # --- parameter counting (for roofline MODEL_FLOPS) ------------------
    def param_counts(self) -> dict:
        """Analytic parameter counts: total and active-per-token."""
        d = self.d_model
        counts = {"embed": self.vocab_size * d,
                  "head": 0 if self.tie_embeddings else self.vocab_size * d}
        per_layer_total = per_layer_active = 0.0
        layers = list(self.prefix_layers) + list(self.period) * (
            (self.n_layers - len(self.prefix_layers)) // max(1, len(self.period)))
        for spec in layers:
            if spec.mixer in (ATTN, SWA):
                if self.use_mla:
                    qh = self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    mix = (d * self.q_lora_rank + self.q_lora_rank * qh
                           + d * (self.kv_lora_rank + self.qk_rope_dim)
                           + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                           + self.n_heads * self.v_head_dim * d)
                else:
                    q = self.n_heads * self.head_dim
                    kv = self.n_kv_heads * self.head_dim
                    mix = d * q + 2 * d * kv + q * d
            elif spec.mixer == MAMBA:
                di, ds = self.mamba_d_inner, self.mamba_d_state
                mix = (d * 2 * di + di * self.mamba_d_conv
                       + di * (self.mamba_dt_rank + 2 * ds)
                       + self.mamba_dt_rank * di + di * ds + di * d)
            elif spec.mixer == RWKV:
                mix = 4 * d * d + d * self.rwkv_decay_lora * 2 + d * d  # r,k,v,g,o + decay lora
            else:
                raise ValueError(spec.mixer)
            if spec.ffn == MOE:
                ffn_tot = self.n_experts * 3 * d * self.d_expert \
                    + self.n_shared_experts * 3 * d * self.d_expert + d * self.n_experts
                ffn_act = (self.top_k + self.n_shared_experts) * 3 * d * self.d_expert \
                    + d * self.n_experts
            else:
                ffn_tot = ffn_act = 3 * d * self.d_ff
            per_layer_total += mix + ffn_tot
            per_layer_active += mix + ffn_act
        counts["layers_total"] = per_layer_total
        counts["layers_active"] = per_layer_active
        counts["total"] = counts["embed"] + counts["head"] + per_layer_total
        counts["active"] = counts["embed"] + counts["head"] + per_layer_active
        return counts


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test variant: same family, tiny dims (2 layers, d_model<=512,
    <=4 experts)."""
    period = cfg.period
    prefix = cfg.prefix_layers
    # keep one period + (maybe) one prefix layer, so the family structure
    # (hybrid interleave, moe placement) survives in miniature.
    n_layers = len(period) + (1 if prefix else 0)
    small = dict(
        n_layers=n_layers,
        prefix_layers=prefix[:1],
        d_model=256,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(2, cfg.n_kv_heads) if cfg.n_kv_heads else 0,
        head_dim=64 if cfg.n_heads else 0,
        d_ff=512,
        vocab_size=512,
        n_experts=min(4, cfg.n_experts),
        top_k=min(2, cfg.top_k),
        d_expert=128 if cfg.n_experts else 0,
        n_shared_experts=min(1, cfg.n_shared_experts),
        sliding_window=64 if cfg.sliding_window else 0,
        mamba_d_state=8,
        mamba_dt_rank=16,
        rwkv_head_dim=64,
        rwkv_decay_lora=16,
        q_lora_rank=64 if cfg.q_lora_rank else 0,
        kv_lora_rank=64 if cfg.kv_lora_rank else 0,
        qk_nope_dim=32 if cfg.qk_nope_dim else 0,
        qk_rope_dim=32 if cfg.qk_rope_dim else 0,
        v_head_dim=64 if cfg.v_head_dim else 0,
        ext_embed_dim=64 if cfg.ext_embed_dim else 0,
        mtp_depth=min(1, cfg.mtp_depth),
        name=cfg.name + "-smoke",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
