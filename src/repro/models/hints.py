"""Sharding hints: a trace-time context that lets deep model internals
(`ring_write`, MoE dispatch) pin intermediate shardings with
``with_sharding_constraint`` without threading mesh plumbing through every
call.  No context -> every hint is a no-op (single-device smoke tests are
untouched).

The launch layer activates hints around tracing (see ``use_hints``); the
rules mirror ``repro.launch.shardings`` and are also the main hillclimb
lever (§Perf).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Callable, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sharding_hints", default=None)


class HintContext:
    def __init__(self, mesh, rule: Callable[[str, tuple], Optional[P]],
                 extras: Optional[dict] = None):
        self.mesh = mesh
        self.rule = rule
        self.extras = extras or {}


@contextlib.contextmanager
def use_hints(mesh, rule, **extras):
    """rule(kind: str, shape: tuple) -> PartitionSpec | None.
    extras: scalar knobs model code may read (e.g. moe_groups)."""
    tok = _CTX.set(HintContext(mesh, rule, extras))
    try:
        yield
    finally:
        _CTX.reset(tok)


def get_extra(key: str, default=None):
    ctx = _CTX.get()
    return default if ctx is None else ctx.extras.get(key, default)


def get_mesh():
    ctx = _CTX.get()
    return None if ctx is None else ctx.mesh


def constrain(x, kind: str):
    """Apply the active hint rule to ``x`` (no-op without a context)."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    spec = ctx.rule(kind, x.shape)
    if spec is None:
        return x
    from repro.launch.shardings import sanitize_spec
    spec = sanitize_spec(spec, x.shape, ctx.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def wrap_with_hints(fn, mesh, rule, **extras):
    """Return fn wrapped so hints are active while it traces/executes."""
    def wrapped(*a, **kw):
        with use_hints(mesh, rule, **extras):
            return fn(*a, **kw)
    return wrapped
