"""Core neural-net layers in raw JAX.

Everything is a pure function over parameter pytrees (nested dicts of
``jnp.ndarray``).  Initializers return the pytree; forward functions take
``(params, inputs, ...)``.  No framework (flax/haiku) is used.

The attention implementation is *blocked* (online-softmax over KV chunks,
flash-attention style) so peak activation memory stays O(S * chunk) even
at 32k/500k contexts — this pure-jnp version is also the oracle for the
Pallas flash-attention kernel in ``repro.kernels``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.hints import constrain

Array = jax.Array

NEG_INF = -1e30  # finite "minus infinity" keeps online softmax NaN-free


def row_dot(x: Array, w: Array) -> Array:
    """Row-parallel matmul (contraction dim sharded): pin the output dtype
    so GSPMD's partial-sum all-reduce travels in x.dtype (bf16), not the
    f32 accumulator."""
    return jax.lax.dot_general(x, w.astype(x.dtype),
                               (((x.ndim - 1,), (0,)), ((), ())),
                               preferred_element_type=x.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, scale: float = 1.0,
               dtype=jnp.bfloat16) -> Array:
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, *, dtype=jnp.bfloat16) -> Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rmsnorm_init(d: int) -> Array:
    return jnp.zeros((d,), jnp.float32)  # stored as (scale - 1)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: Array, positions: Array, theta: float = 10_000.0) -> Array:
    """Rotary embedding.  x: (..., S, H, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    assert d % 2 == 0, d
    freq = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)   # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freq            # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (...,S,1,D/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocked (online-softmax) attention — GQA, causal, sliding-window, softcap
# ---------------------------------------------------------------------------

def attention(q: Array, k: Array, v: Array, q_pos: Array, kv_pos: Array, *,
              window: int = 0, causal: bool = True, softcap: float = 0.0,
              kv_chunk: int = 1024, scale: Optional[float] = None,
              q_extra: Optional[Array] = None,
              k_extra: Optional[Array] = None,
              table: Optional[Array] = None,
              use_kernel: bool = False) -> Array:
    """Flash-style attention.

    q: (B, S, Hq, D); k: (B, T, Hkv, D); v: (B, T, Hkv, Dv) (Dv may differ,
    e.g. MLA-absorbed decode where v is the latent);
    q_pos: (B, S) int32 query positions; kv_pos: (B, T) int32 key positions,
    entries < 0 mark invalid (unwritten cache) slots.
    window > 0 limits attention to keys with q_pos - kv_pos < window.
    q_extra/k_extra: optional SECOND score contraction added before the
    softmax (scores = q·kᵀ + q_extra·k_extraᵀ) — MLA decode keeps the
    latent and rope caches separate this way instead of concatenating
    differently-sharded tensors (dot distributes over concat, so the math
    is identical).

    Paged mode (``table`` given): k / v / kv_pos (and k_extra) are block
    POOLS of shape (num_blocks, page, Hkv, D*) / (num_blocks, page) and
    ``table`` is a (B, n_cols) int32 block table mapping each row's
    logical page to a pool block; entries < 0 are unallocated pages
    (fully masked).  Each online-softmax step gathers one chunk of blocks
    from the pool, so peak activation memory stays O(B * kv_chunk)
    regardless of pool size, and the masking/accumulation math is
    identical to the dense path — unallocated or unwritten entries carry
    position -1 and contribute exactly-zero probability mass.

    ``use_kernel=True`` dispatches paged single-token decode (``table``
    given, S == 1, causal) to the fused Pallas kernel
    (``repro.kernels.paged_attention``): the block table is
    scalar-prefetched and drives the page DMA, so the per-chunk
    ``pool[safe_table]`` gather below — which materializes a
    (B, C, Hkv, D) K/V copy in HBM every online-softmax step — never
    happens.  All other shapes (prefill chunks, dense caches) keep this
    scan path, which remains the reference semantics.

    Returns (B, S, Hq, Dv) in q.dtype; accumulation in float32.
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    Dv = v.shape[-1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    if use_kernel and table is not None and S == 1 and causal:
        from repro.kernels import ops
        return ops.paged_attention(q, k, v, kv_pos, table, q_pos,
                                   scale=scale, window=window,
                                   softcap=softcap, q_extra=q_extra,
                                   k_extra=k_extra)
    qf = q.astype(jnp.float32).reshape(B, S, Hkv, G, D) * scale
    qe = None
    if q_extra is not None:
        De = q_extra.shape[-1]
        qe = q_extra.astype(jnp.float32).reshape(B, S, Hkv, G, De) * scale

    if table is None:
        T = k.shape[1]
        C = min(kv_chunk, T)
        n_chunks = -(-T // C)
        pad = n_chunks * C - T
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
            if k_extra is not None:
                k_extra = jnp.pad(k_extra, ((0, 0), (0, pad), (0, 0), (0, 0)))

        def chunk_at(idx):
            # k/v stay loop-invariant (no transposed copy of the cache);
            # each step dynamic-slices one chunk.
            kj = jax.lax.dynamic_slice_in_dim(k, idx * C, C, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v, idx * C, C, axis=1)
            pj = jax.lax.dynamic_slice_in_dim(kv_pos, idx * C, C, axis=1)
            kej = (jax.lax.dynamic_slice_in_dim(k_extra, idx * C, C, axis=1)
                   if k_extra is not None else None)
            return kj, vj, pj, kej
    else:
        page = k.shape[1]
        n_cols = table.shape[1]
        # blocks per online-softmax step: cover ~kv_chunk positions so the
        # chunk partition (and hence fp accumulation order) matches the
        # dense path whenever page | kv_chunk.
        cb = max(1, min(kv_chunk, n_cols * page) // page)
        n_chunks = -(-n_cols // cb)
        padb = n_chunks * cb - n_cols
        tab = (jnp.pad(table, ((0, 0), (0, padb)), constant_values=-1)
               if padb else table)
        C = cb * page

        def chunk_at(idx):
            tj = jax.lax.dynamic_slice_in_dim(tab, idx * cb, cb, axis=1)
            safe = jnp.maximum(tj, 0)                         # (B, cb)
            kj = k[safe].reshape(B, C, Hkv, k.shape[-1])
            vj = v[safe].reshape(B, C, Hkv, Dv)
            pj = jnp.where((tj >= 0)[..., None], kv_pos[safe],
                           -1).reshape(B, C)
            kej = (k_extra[safe].reshape(B, C, Hkv, k_extra.shape[-1])
                   if k_extra is not None else None)
            return kj, vj, pj, kej

    m0 = jnp.full((B, S, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, S, Hkv, G, Dv), jnp.float32)

    def body(carry, idx):
        m, l, acc = carry
        kj, vj, pj, kej = chunk_at(idx)
        s = jnp.einsum("bsngd,bcnd->bsngc", qf, kj.astype(jnp.float32))
        if qe is not None:
            s = s + jnp.einsum("bsngd,bcnd->bsngc", qe,
                               kej.astype(jnp.float32))
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        ok = pj[:, None, :] >= 0                              # (B,1,C) valid
        if causal:
            ok &= pj[:, None, :] <= q_pos[:, :, None]
        if window > 0:
            ok &= pj[:, None, :] > q_pos[:, :, None] - window
        s = jnp.where(ok[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        # fully-masked chunks: p would be exp(NEG_INF - NEG_INF)=1; zero them
        p = jnp.where(ok[:, :, None, None, :], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bsngc,bcnd->bsngd", p, vj.astype(jnp.float32))
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  jnp.arange(n_chunks, dtype=jnp.int32))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, S, Hq, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (projections + rope + cache handling)
# ---------------------------------------------------------------------------

def attn_init(key, cfg) -> dict:
    ks = jax.random.split(key, 5)
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(ks[0], d, hq * hd),
        "wk": dense_init(ks[1], d, hkv * hd),
        "wv": dense_init(ks[2], d, hkv * hd),
        "wo": dense_init(ks[3], hq * hd, d, scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((hq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    return p


def swa_ring_blocks(window: int, page_size: int, n_cols: int) -> int:
    """Number of block-table columns a sliding-window layer cycles over:
    the smallest whole-page ring covering ``window`` positions, clamped to
    the table width (mirrors the dense ``ring = min(window, cache_len)``)."""
    return max(1, min(-(-window // page_size), n_cols))


def attn_apply(p: dict, x: Array, cfg, *, positions: Array,
               cache: Optional[dict] = None, window: int = 0,
               kv_chunk: int = 1024, masked_slots: bool = False,
               table: Optional[Array] = None, use_kernel: bool = False):
    """x: (B,S,d). cache (decode): {"k","v": (B,T,Hkv,D), "pos": (B,T)},
    or a paged pool {"k","v": (N,page,Hkv,D), "pos": (N,page)} when a
    (B, n_cols) block ``table`` is given — writes scatter through the
    table and attention gathers pages chunk-wise (SWA layers cycle over
    the first ``swa_ring_blocks`` table columns as ring pages).
    ``masked_slots=True`` selects the per-row masked cache write
    (continuous-batching chunked prefill: rows with position -1 are
    write no-ops).  Returns (out, new_cache)."""
    B, S, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.attn_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, hq, hd)
    k = k.reshape(B, S, hkv, hd)
    v = v.reshape(B, S, hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = constrain(rope(q, positions, cfg.rope_theta), "attn_q")
    k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    attn_table = None
    if cache is not None and table is not None:
        # ---- paged pool + block table ---------------------------------
        # per-cache-kind tables: engines with split block-id spaces pass
        # {"attn": (B, n_cols), "swa": (B, ring_blocks)}; a bare array is
        # one shared table for every attention-family layer (back-compat)
        if isinstance(table, dict):
            table = table["swa" if window > 0 else "attn"]
        page = cache["k"].shape[1]
        if window > 0:
            nb = swa_ring_blocks(window, page, table.shape[1])
            tab, ring = table[:, :nb], nb * page
        else:
            tab, ring = table, 0
        if masked_slots and S > 1 and window > 0:
            # same eviction hazard as the dense ring (below): gather the
            # pre-write ring pages, attend over [old ring ∥ chunk], write
            # separately.  The gathered ring is window-sized, so this
            # stays cheap.
            old_k = gather_pages(cache["k"], tab)
            old_v = gather_pages(cache["v"], tab)
            old_pos = gather_pos(cache["pos"], tab)
            new_cache = paged_cache_update(cache, k, v, positions, tab,
                                           ring=ring)
            k = jnp.concatenate([old_k, k.astype(old_k.dtype)], axis=1)
            v = jnp.concatenate([old_v, v.astype(old_v.dtype)], axis=1)
            kv_pos = jnp.concatenate([old_pos, positions], axis=1)
        else:
            new_cache = paged_cache_update(cache, k, v, positions, tab,
                                           ring=ring)
            k, v = new_cache["k"], new_cache["v"]
            kv_pos = new_cache["pos"]
            attn_table = tab
    elif cache is not None:
        if masked_slots and S > 1 and window > 0:
            # chunked prefill against a populated sliding-window ring:
            # writing the chunk first can EVICT keys still inside the
            # earliest in-chunk queries' windows (ring shorter than the
            # prompt).  Attend over [cache-before-write ∥ current chunk]
            # — position masks give exact semantics, pre-write slots hold
            # older positions (or -1), so nothing is double-counted — and
            # write separately.  Full caches (window == 0) never wrap, so
            # they take the cheaper write-then-attend path below.
            old_k, old_v, old_pos = cache["k"], cache["v"], cache["pos"]
            _, _, _, new_cache = cache_update(cache, k, v, positions,
                                              per_row=True)
            k = jnp.concatenate([old_k, k.astype(old_k.dtype)], axis=1)
            v = jnp.concatenate([old_v, v.astype(old_v.dtype)], axis=1)
            kv_pos = jnp.concatenate([old_pos, positions], axis=1)
        else:
            full_k, full_v, kv_pos, new_cache = cache_update(
                cache, k, v, positions, per_row=masked_slots)
            if S <= cache["k"].shape[1]:
                k, v = full_k, full_v
            else:
                # sliding-window prefill into a ring shorter than the
                # sequence: the ring only serves subsequent decode; attend
                # over the local in-sequence keys (window mask below gives
                # exact semantics).
                kv_pos = positions
    else:
        kv_pos = positions
    out = attention(q, k, v, positions, kv_pos, window=window,
                    softcap=cfg.logits_softcap, kv_chunk=kv_chunk,
                    table=attn_table, use_kernel=use_kernel)
    return row_dot(out.reshape(B, S, hq * hd), p["wo"]), new_cache


# ---------------------------------------------------------------------------
# KV cache (linear or ring-buffer)
# ---------------------------------------------------------------------------

def cache_init(batch: int, cache_len: int, n_kv: int, head_dim: int,
               dtype=jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((batch, cache_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, n_kv, head_dim), dtype),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def paged_cache_init(num_blocks: int, page_size: int, n_kv: int,
                     head_dim: int, dtype=jnp.bfloat16) -> dict:
    """Paged KV pool: ``num_blocks`` pages of ``page_size`` positions,
    shared by all serving slots through a per-slot block table (the table
    itself is host-managed and passed into the step separately)."""
    return {
        "k": jnp.zeros((num_blocks, page_size, n_kv, head_dim), dtype),
        "v": jnp.zeros((num_blocks, page_size, n_kv, head_dim), dtype),
        "pos": jnp.full((num_blocks, page_size), -1, jnp.int32),
    }


def ring_write(buf: Array, val: Array, positions: Array,
               kind: str = "", per_row: bool = False, *,
               table: Optional[Array] = None, ring: int = 0) -> Array:
    """SPMD-friendly ring-buffer write (no scatter, so GSPMD never
    all-gathers the cache).

    buf: (B, T, ...); val: (B, S, ...); positions: (B, S), slot = pos % T.
    Entries with position < 0 are never written (masked serving slots).

    * S == 1 (decode): one-hot select over T — pure elementwise.
    * S > 1, per_row=False (hot-path prefill): positions are assumed
      contiguous AND row-uniform, starting at positions[0,0]; the value
      block is placed by a roll so wrapped rings stay correct.
    * S > 1, per_row=True (continuous-batching chunked prefill): rows may
      start at different slots and carry invalid (pos < 0) entries; each
      row is placed by a gather-roll and merged entry-wise on position
      validity, so idle slots and padded tails are write no-ops.
    * table given (paged pool): buf is a block pool (N, page, ...); each
      (row, step) entry scatters into
      ``pool[table[row, logical // page], logical % page]`` where
      ``logical = pos % ring`` for SWA ring pages (``ring`` > 0) and
      ``logical = pos`` otherwise.  Entries with position < 0 or an
      unallocated (-1) table page are dropped — position -1 stays a write
      no-op, exactly as in the dense paths.
    """
    pin = (lambda x: constrain(x, f"cache/{kind}")) if kind else (lambda x: x)
    if table is not None:
        N, page = buf.shape[0], buf.shape[1]
        n_cols = table.shape[1]
        cap = ring if ring else n_cols * page
        if val.shape[1] > cap:          # SWA chunk longer than the ring:
            val = val[:, -cap:]         # only the last `cap` entries survive
            positions = positions[:, -cap:]
        logical = positions % cap if ring else positions
        col = logical // page
        blk = jnp.take_along_axis(table, jnp.clip(col, 0, n_cols - 1), axis=1)
        ok = (positions >= 0) & (col >= 0) & (col < n_cols) & (blk >= 0)
        blk = jnp.where(ok, blk, N)     # out-of-pool index -> dropped
        off = logical % page
        flat = lambda a: a.reshape((-1,) + a.shape[2:])
        return pin(buf.at[flat(blk), flat(off)].set(
            flat(val.astype(buf.dtype)), mode="drop"))
    T = buf.shape[1]
    S = val.shape[1]
    val = val.astype(buf.dtype)
    trail = (1,) * (buf.ndim - 2)
    if S == 1:
        slot = positions % T                                  # (B,1)
        hit = (jnp.arange(T, dtype=jnp.int32)[None, :] == slot) \
            & (positions >= 0)                                 # (B,T)
        hit = hit.reshape(hit.shape + trail)
        return pin(jnp.where(hit, val, buf))
    if S > T:
        val, positions = val[:, -T:], positions[:, -T:]
        S = T
    if per_row:
        # wrap-safe per-row placement: out[b, j] <- val[b, (j - p0_b) % T]
        p0 = positions[:, :1] % T                              # (B,1)
        if S < T:
            val = jnp.pad(val, ((0, 0), (0, T - S)) + ((0, 0),) * (val.ndim - 2))
            positions = jnp.pad(positions, ((0, 0), (0, T - S)),
                                constant_values=-1)
        src = (jnp.arange(T, dtype=jnp.int32)[None, :] - p0) % T  # (B,T)
        rolled = jnp.take_along_axis(val, src.reshape(src.shape + trail),
                                     axis=1)
        written = jnp.take_along_axis(positions, src, axis=1) >= 0
        return pin(jnp.where(written.reshape(written.shape + trail),
                             rolled, buf))
    if S == T:
        shift = positions[0, 0] % T
        return pin(jnp.roll(val, shift, axis=1))
    # S < T, no wrap assumed (prefill from slot p0, p0 + S <= T)
    p0 = positions[0, 0] % T
    return pin(jax.lax.dynamic_update_slice_in_dim(buf, val, p0, axis=1))


def cache_update(cache: dict, k: Array, v: Array, positions: Array,
                 per_row: bool = False):
    """Write S new entries at slot = position % cache_len (ring buffer;
    for full caches cache_len >= max position so the ring never wraps).
    When S > cache_len (sliding-window prefill) only the last cache_len
    entries are written.  ``per_row=True`` selects the masked per-row
    write (continuous-batching chunked prefill — see ``ring_write``).
    Returns (full_k, full_v, kv_pos, new_cache)."""
    T = cache["k"].shape[1]
    if k.shape[1] > T:
        k, v, positions = k[:, -T:], v[:, -T:], positions[:, -T:]
    new = {
        "k": ring_write(cache["k"], k, positions, kind="k", per_row=per_row),
        "v": ring_write(cache["v"], v, positions, kind="v", per_row=per_row),
        "pos": ring_write(cache["pos"], positions, positions, kind="pos",
                          per_row=per_row),
    }
    return new["k"], new["v"], new["pos"], new


def paged_cache_update(cache: dict, k: Array, v: Array, positions: Array,
                       table: Array, ring: int = 0) -> dict:
    """Scatter S new entries into the paged pool through the block table
    (position -1 and unallocated pages are write no-ops).  Returns the new
    cache pytree; reads go back through ``attention(..., table=...)`` or a
    gather, so no dense (B, T, ...) view is materialized here."""
    return {
        "k": ring_write(cache["k"], k, positions, kind="k", table=table,
                        ring=ring),
        "v": ring_write(cache["v"], v, positions, kind="v", table=table,
                        ring=ring),
        "pos": ring_write(cache["pos"], positions, positions, kind="pos",
                          table=table, ring=ring),
    }


def gather_pages(pool: Array, table: Array):
    """Dense (B, n_cols * page, ...) view of a paged pool through the block
    table; unallocated (-1) pages read block 0 and must be masked by the
    caller (use ``gather_pos`` for positions, whose invalid entries become
    -1)."""
    B, n_cols = table.shape
    page = pool.shape[1]
    return pool[jnp.maximum(table, 0)].reshape(
        (B, n_cols * page) + pool.shape[2:])


def gather_pos(pos_pool: Array, table: Array) -> Array:
    """Dense (B, n_cols * page) positions view; unallocated pages -> -1."""
    B, n_cols = table.shape
    page = pos_pool.shape[1]
    got = jnp.where((table >= 0)[..., None], pos_pool[jnp.maximum(table, 0)],
                    -1)
    return got.reshape(B, n_cols * page)


# ---------------------------------------------------------------------------
# Dense FFN (SwiGLU)
# ---------------------------------------------------------------------------

def ffn_init(key, cfg, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d, f),
        "w_up": dense_init(ks[1], d, f),
        "w_down": dense_init(ks[2], f, d, scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }


def ffn_apply(p: dict, x: Array) -> Array:
    g = jax.nn.silu(x @ p["w_gate"].astype(x.dtype))
    u = x @ p["w_up"].astype(x.dtype)
    h = constrain(g * u, "ffn_hidden")
    return row_dot(h, p["w_down"])


# ---------------------------------------------------------------------------
# Mixture of Experts — sort-based dispatch (no (T,E,C) one-hot einsums)
# ---------------------------------------------------------------------------

def moe_init(key, cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_expert, cfg.n_experts
    ks = jax.random.split(key, 5)

    def stack_init(k, d_in, d_out, scale=1.0):
        return jax.vmap(lambda kk: dense_init(kk, d_in, d_out, scale=scale))(
            jax.random.split(k, e))

    p = {
        "router": dense_init(ks[0], d, e, dtype=jnp.float32),
        "w_gate": stack_init(ks[1], d, f),
        "w_up": stack_init(ks[2], d, f),
        "w_down": stack_init(ks[3], f, d, scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.n_shared_experts:
        import dataclasses as _dc
        shared_cfg = _dc.replace(cfg, d_ff=cfg.d_expert * cfg.n_shared_experts)
        p["shared"] = ffn_init(ks[4], shared_cfg, shared_cfg.d_ff)
    return p


def _moe_dispatch_group(xt: Array, gate_vals: Array, expert_idx: Array,
                        E: int, K: int, C: int):
    """Sort-based dispatch for ONE token group.  xt: (Tg, d);
    gate_vals/expert_idx: (Tg, K).  Returns (buf (E,C,d), slot, src_token,
    gates_sorted) for the gather-back."""
    Tg, d = xt.shape
    flat_e = expert_idx.reshape(Tg * K)
    order = jnp.argsort(flat_e)                                  # stable
    sorted_e = flat_e[order]
    first_of_group = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(Tg * K) - first_of_group
    keep = rank < C
    slot = jnp.where(keep, sorted_e * C + rank, E * C)           # E*C = trash
    src_token = order // K
    buf = jnp.zeros((E * C + 1, d), xt.dtype).at[slot].set(
        xt[src_token] * keep[:, None].astype(xt.dtype))
    gates_sorted = gate_vals.reshape(Tg * K)[order] * keep
    return buf[:-1].reshape(E, C, d), slot, src_token, gates_sorted


def moe_apply(p: dict, x: Array, cfg) -> tuple[Array, Array]:
    """Sort-based top-k MoE with **grouped dispatch**.  x: (B,S,d) ->
    (out, aux_loss).

    Tokens split into ``moe_groups`` contiguous groups (the launch layer
    sets this to the data-parallel shard count via sharding hints); each
    group builds its own per-expert capacity buffer with a group-local
    argsort + gather, and experts run over the (G, E, C, d) buffer with E
    sharded on the model axis (expert parallelism).  All data-dependent
    gathers/scatters stay group-local, so GSPMD never materializes or
    all-reduces a (T·K, d) tensor — the cross-device movement is the
    buffer all-to-all, as in a real EP system.  Tokens beyond per-group
    capacity are dropped (capacity-factor semantics).
    """
    from repro.models.hints import get_extra, get_mesh
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    mesh = get_mesh()
    if mesh is not None and get_extra("moe_ep", False):
        from repro.launch.mesh import data_axes, model_axis
        from repro.models.moe_ep import moe_apply_ep
        dp, mp = data_axes(mesh), model_axis(mesh)
        n_mp = mesh.shape[mp]
        n_dp = 1
        for a in dp:
            n_dp *= mesh.shape[a]
        if (E % n_mp == 0 and B % n_dp == 0
                and (B // n_dp) * S % n_mp == 0):
            return moe_apply_ep(p, x, cfg, mesh, dp, mp)
    G = int(get_extra("moe_groups", 1))
    if T % G != 0:
        G = 1
    Tg = T // G
    C = max(1, int(math.ceil(Tg * K / E * cfg.capacity_factor)))
    C = -(-C // 8) * 8                 # layout-friendly multiple of 8
    C = min(C, Tg * K)

    xt = x.reshape(T, d)
    logits = (xt.astype(jnp.float32) @ p["router"])              # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)              # (T,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- load-balance auxiliary loss (Switch-style) ---------------------
    me = probs.mean(axis=0)                                      # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (T * K))
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # --- grouped dispatch -------------------------------------------------
    disp = jax.vmap(
        lambda xg, gg, eg: _moe_dispatch_group(xg, gg, eg, E, K, C))
    buf, slot, src_token, gates_sorted = disp(
        xt.reshape(G, Tg, d), gate_vals.reshape(G, Tg, K),
        expert_idx.reshape(G, Tg, K))
    # pin the scatter output group-local (scatters stay on-shard), then
    # reshard to expert-sharded — an explicit buffer all-to-all, the EP
    # boundary a real expert-parallel system would have.  The barrier stops
    # GSPMD from collapsing the two constraints into one.
    buf = constrain(buf, "moe_buffer_local")
    buf = jax.lax.optimization_barrier(buf)
    buf = constrain(buf, "moe_buffer")                           # (G,E,C,d)

    # --- batched expert FFN (E sharded on model -> expert parallelism) ---
    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf,
                               p["w_gate"].astype(x.dtype)))
    u = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(x.dtype))
    h = jnp.einsum("gecf,efd->gecd", g * u, p["w_down"].astype(x.dtype))
    h = constrain(h, "moe_h")
    h = jax.lax.optimization_barrier(h)
    h = constrain(h, "moe_h_local")     # reverse a2a: back to group-local

    # --- gather back + combine with gates (group-local scatter-add) ------
    def comb(hg, sg, tg, gg):
        h_flat = jnp.concatenate([hg.reshape(E * C, d),
                                  jnp.zeros((1, d), hg.dtype)], axis=0)
        per_assign = h_flat[sg]
        return jnp.zeros((Tg, d), jnp.float32).at[tg].add(
            per_assign.astype(jnp.float32) * gg[:, None])

    out = jax.vmap(comb)(h, slot, src_token, gates_sorted).reshape(T, d)
    out = constrain(out.astype(x.dtype), "moe_tokens")
    if "shared" in p:
        out = out + ffn_apply(p["shared"], xt)
    return out.reshape(B, S, d), aux
