"""True expert-parallel MoE via shard_map (beyond-paper optimization).

GSPMD's auto-partitioning of the sort-based MoE implements the
token<->expert movement as mask + (T·K, d) all-reduces (≈34 GB/chip/layer
for qwen3-moe-235b train_4k).  A real EP system moves only routed token
vectors through all-to-alls.  This module is that system:

per (data, model) rank — the model axis carries experts (E_local = E/n_mp):
 1. take my 1/n_mp strip of the local batch's tokens (sequence split);
 2. route locally (top-k);
 3. sort assignments by DESTINATION RANK into a (n_mp, C_send, d) buffer
    -> ``lax.all_to_all`` over the model axis (token vectors + local
    expert ids travel; gates and source slots stay home);
 4. second local sort by LOCAL EXPERT into the (E_local, C_local, d)
    compute buffer -> batched expert FFN;
 5. gather back to arrival order -> reverse all-to-all;
 6. combine at the source strip (gates applied), all-gather strips over
    the model axis to rebuild the replicated residual.

Per-chip per-layer traffic = 2 a2a of (n_mp·C_send·d) + strip gather —
O(tokens·d·K/n_ranks), independent of E.  Differentiable end to end
(shard_map + collectives transpose cleanly).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import ffn_apply

Array = jax.Array


def _sort_dispatch(xt, keys, n_buckets: int, cap: int, payload=()):
    """Sort-based bucket dispatch.  xt: (N, d); keys: (N,) int32 in
    [0, n_buckets) (negative = invalid).  Returns (buf (n_buckets, cap, d),
    slot (N,), keep (N,), *payload_bufs) where payload entries are (N,)
    arrays scattered alongside (fill -1 / 0)."""
    N, d = xt.shape
    keys_sort = jnp.where(keys < 0, n_buckets, keys)   # invalid to the end
    order = jnp.argsort(keys_sort)
    sorted_k = keys_sort[order]
    first = jnp.searchsorted(sorted_k, sorted_k, side="left")
    rank = jnp.arange(N) - first
    keep = (rank < cap) & (sorted_k < n_buckets)
    slot = jnp.where(keep, sorted_k * cap + rank, n_buckets * cap)
    buf = jnp.zeros((n_buckets * cap + 1, d), xt.dtype).at[slot].set(
        xt[order] * keep[:, None].astype(xt.dtype))
    outs = [buf[:-1].reshape(n_buckets, cap, d)]
    for pay, fill in payload:
        pbuf = jnp.full((n_buckets * cap + 1,), fill, pay.dtype).at[slot].set(
            jnp.where(keep, pay[order], fill))
        outs.append(pbuf[:-1].reshape(n_buckets, cap))
    return outs, order, slot, keep


def moe_apply_ep(p: dict, x: Array, cfg, mesh, dp_axes, mp_axis: str
                 ) -> tuple[Array, Array]:
    """Expert-parallel MoE.  x: (B, S, d) sharded P(dp, None, None)
    (batch over data, replicated over model).  Returns (out, aux)."""
    from jax.experimental.shard_map import shard_map

    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    n_mp = mesh.shape[mp_axis]
    n_dp = 1
    for a in (dp_axes if isinstance(dp_axes, tuple) else (dp_axes,)):
        n_dp *= mesh.shape[a]
    E_local = E // n_mp
    B_l = B // n_dp
    T_l = B_l * S                       # tokens per data shard
    assert T_l % n_mp == 0
    T_strip = T_l // n_mp               # my token strip
    cf = cfg.capacity_factor
    c_send = -(-int(math.ceil(T_strip * K / n_mp * cf)) // 8) * 8
    c_send = min(c_send, T_strip * K)
    c_loc = -(-int(math.ceil(T_strip * K / E_local * cf)) // 8) * 8
    c_loc = min(c_loc, n_mp * c_send)

    dp = dp_axes if isinstance(dp_axes, tuple) else (dp_axes,)
    dps = dp if len(dp) > 1 else dp[0]
    all_axes = tuple(dp) + (mp_axis,)

    def body(x_l, router, wg, wu, wd, shared_g, shared_u, shared_d):
        # x_l: (B_l, S, d) replicated over mp; weights: local expert slices
        r = jax.lax.axis_index(mp_axis)
        xt_full = x_l.reshape(T_l, d)
        xt = jax.lax.dynamic_slice_in_dim(xt_full, r * T_strip, T_strip, 0)

        logits = xt.astype(jnp.float32) @ router            # (T_strip, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, eidx = jax.lax.top_k(probs, K)                # (T_strip, K)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        # global load-balance aux (psum of strip sums over every axis)
        me = jax.lax.psum(probs.sum(0), all_axes) / (T_l * n_dp)
        ce = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0)
        ce = jax.lax.psum(ce, all_axes) / (T_l * n_dp * K)
        aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

        # ---- stage 1: bucket by destination rank, a2a ------------------
        N = T_strip * K
        flat_e = eidx.reshape(N)
        dest = flat_e // E_local
        xt_rep = jnp.repeat(xt, K, axis=0)                   # (N, d) token per assignment
        (send, send_le), order, slot, keep = _sort_dispatch(
            xt_rep, dest, n_mp, c_send,
            payload=[(flat_e % E_local, -1)])
        recv = jax.lax.all_to_all(send.astype(jnp.bfloat16), mp_axis, 0, 0)
        recv = recv.astype(x_l.dtype)                        # (n_mp, c_send, d)
        recv_le = jax.lax.all_to_all(send_le, mp_axis, 0, 0)

        # ---- stage 2: bucket by local expert, run experts ---------------
        rt = recv.reshape(n_mp * c_send, d)
        rle = recv_le.reshape(n_mp * c_send)
        (ebuf,), order2, slot2, keep2 = _sort_dispatch(rt, rle, E_local,
                                                       c_loc)
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf, wg))
        u = jnp.einsum("ecd,edf->ecf", ebuf, wu)
        h = jnp.einsum("ecf,efd->ecd", g * u, wd)            # (E_local,c_loc,d)

        # gather back to arrival order
        h_flat = jnp.concatenate([h.reshape(E_local * c_loc, d),
                                  jnp.zeros((1, d), h.dtype)], 0)
        back_sorted = h_flat[slot2]                          # sorted order
        back = jnp.zeros((n_mp * c_send, d), h.dtype).at[order2].set(
            back_sorted)
        back = back.reshape(n_mp, c_send, d)
        ret = jax.lax.all_to_all(back.astype(jnp.bfloat16), mp_axis, 0, 0)
        ret = ret.astype(x_l.dtype)                          # home again

        # ---- combine at source strip ------------------------------------
        ret_flat = jnp.concatenate([ret.reshape(n_mp * c_send, d),
                                    jnp.zeros((1, d), ret.dtype)], 0)
        per_assign_sorted = ret_flat[slot]   # sorted order (dropped -> 0)
        per_assign = jnp.zeros((N, d), ret.dtype).at[order].set(
            per_assign_sorted)               # back to assignment order
        gates_flat = gates.reshape(N).astype(jnp.float32)
        src = jnp.arange(N) // K
        out = jnp.zeros((T_strip, d), jnp.float32).at[src].add(
            per_assign.astype(jnp.float32) * gates_flat[:, None])
        out = out.astype(x.dtype)

        if shared_g is not None:
            # shared expert: replicated weights, strip-local compute (a
            # psum over f-sliced weights would mix different ranks' strips)
            sh_g = jax.nn.silu(xt @ shared_g)
            out = out + (sh_g * (xt @ shared_u)) @ shared_d

        # rebuild the replicated residual strip layout (bf16 on the wire)
        out_full = jax.lax.all_gather(out.astype(jnp.bfloat16), mp_axis,
                                      axis=0, tiled=True).astype(x_l.dtype)
        return out_full.reshape(B_l, S, d), aux

    shared = "shared" in p
    in_specs = (P(dps, None, None), P(None, None),
                P(mp_axis, None, None), P(mp_axis, None, None),
                P(mp_axis, None, None),
                P(None, None) if shared else None,
                P(None, None) if shared else None,
                P(None, None) if shared else None)
    fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                   out_specs=(P(dps, None, None), P()), check_rep=False)
    wdt = x.dtype
    out, aux = fn(x, p["router"].astype(jnp.float32),
                  p["w_gate"].astype(wdt), p["w_up"].astype(wdt),
                  p["w_down"].astype(wdt),
                  p["shared"]["w_gate"].astype(wdt) if shared else None,
                  p["shared"]["w_up"].astype(wdt) if shared else None,
                  p["shared"]["w_down"].astype(wdt) if shared else None)
    return out, aux
