"""Training-loop and serving integration tests: loss decreases on the
structured synthetic stream, checkpoints roundtrip and resume, microbatch
accumulation is consistent, generation == teacher forcing, the continuous
batcher reproduces plain generate()."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs import get_smoke_config
from repro.data.synthetic import DataProvider, SyntheticConfig, SyntheticLM
from repro.core.dht import DHT
from repro.models.transformer import forward, init_params
from repro.optim.adamw import adamw, cosine_lr, global_norm
from repro.serve.engine import Request, ServingEngine, generate
from repro.train.loss import cross_entropy_chunked
from repro.train.step import make_train_step
from repro.train.trainer import TrainConfig, Trainer


def _tiny_cfg():
    cfg = get_smoke_config("gpt3-24l")
    return dataclasses.replace(cfg, vocab_size=128, d_model=128, d_ff=256,
                               n_heads=4, n_kv_heads=4, head_dim=32)


def test_trainer_loss_decreases():
    cfg = _tiny_cfg()
    loader = SyntheticLM(SyntheticConfig(vocab_size=cfg.vocab_size,
                                         seq_len=64, global_batch=8,
                                         noise=0.05))
    trainer = Trainer(cfg, TrainConfig(steps=60, lr=3e-3, warmup=10,
                                       log_every=20), loader)
    hist = trainer.fit(log=lambda s: None)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert last < first * 0.7, (first, last)
    assert last < np.log(cfg.vocab_size)  # beats uniform guessing


def test_chunked_ce_matches_direct():
    key = jax.random.PRNGKey(0)
    B, S, d, V = 2, 37, 16, 50
    h = jax.random.normal(key, (B, S, d), jnp.float32)
    head = jax.random.normal(key, (d, V), jnp.float32)
    labels = jax.random.randint(key, (B, S), 0, V)
    loss, acc = cross_entropy_chunked(h, head, labels, chunk=8)
    logits = h.reshape(-1, d) @ head
    logp = jax.nn.log_softmax(logits)
    direct = -jnp.take_along_axis(logp, labels.reshape(-1, 1), 1).mean()
    np.testing.assert_allclose(float(loss), float(direct), rtol=1e-5)


def test_microbatch_accumulation_consistent():
    cfg = _tiny_cfg()
    loader = SyntheticLM(SyntheticConfig(cfg.vocab_size, 32, 8))
    batch = loader.batch(0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(1e-3)
    s1 = jax.jit(make_train_step(cfg, opt, microbatches=1))
    s4 = jax.jit(make_train_step(cfg, opt, microbatches=4))
    p1, _, m1 = s1(params, opt.init(params), batch)
    p4, _, m4 = s4(params, opt.init(params), batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 0.02
    diff = global_norm(jax.tree.map(
        lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32), p1, p4))
    assert float(diff) < 0.5 * float(global_norm(p1))


def test_checkpoint_roundtrip_and_resume():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(1e-3)
    state = opt.init(params)
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 7, (params, state))
        (p2, s2), step = store.restore(d, (params, state))
        assert step == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # retention: keep only 2
        store.save(d, 8, (params, state), keep=2)
        store.save(d, 9, (params, state), keep=2)
        store.save(d, 10, (params, state), keep=2)
        assert store.latest_step(d) == 10
        import os
        assert len([x for x in os.listdir(d) if x.startswith("step_")]) == 2


def test_synthetic_stream_structure():
    lm = SyntheticLM(SyntheticConfig(vocab_size=256, seq_len=64,
                                     global_batch=4, noise=0.1))
    b0a, b0b, b1 = lm.batch(0), lm.batch(0), lm.batch(1)
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])  # determinism
    assert not np.array_equal(np.asarray(b0a["tokens"]),
                              np.asarray(b1["tokens"]))
    # labels are next-tokens
    assert lm.optimal_loss() < np.log(256) / 2


def test_dht_data_provider():
    lm = SyntheticLM(SyntheticConfig(vocab_size=64, seq_len=16,
                                     global_batch=2))
    dht = DHT(range(4), replication=2)
    dp = DataProvider(lm, dht)
    assert dp.publish(0, 3) == 3
    fetched = dp.fetch(1)
    np.testing.assert_array_equal(np.asarray(fetched["tokens"]),
                                  np.asarray(lm.batch(1)["tokens"]))
    # miss falls back to regeneration
    np.testing.assert_array_equal(np.asarray(dp.fetch(99)["tokens"]),
                                  np.asarray(lm.batch(99)["tokens"]))


def test_generate_matches_teacher_forcing():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(3), cfg)
    prompts = jnp.array([[5, 9, 2]], jnp.int32)
    out = generate(params, cfg, prompts, max_new=6)
    # teacher-force the generated sequence; greedy argmax must reproduce it
    logits, _, _ = forward(params, cfg, {"tokens": out})
    for t in range(3 - 1, out.shape[1] - 1):
        assert int(out[0, t + 1]) == int(jnp.argmax(logits[0, t]))


def test_serving_engine_matches_generate():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(4), cfg)
    eng = ServingEngine(params, cfg, slots=2, cache_len=64)
    for i in range(3):
        eng.submit(Request(i, [1, 2, 3], max_new=4))
    done = sorted(eng.run(), key=lambda r: r.req_id)
    ref = generate(params, cfg, jnp.array([[1, 2, 3]], jnp.int32),
                   max_new=4)[0, 3:].tolist()
    for r in done:
        assert r.generated == ref, (r.req_id, r.generated, ref)


@pytest.mark.parametrize("arch", ["rwkv6-7b", "gemma3-12b"])
def test_slot_reuse_isolation(arch):
    """A request admitted into a reused slot must not see the previous
    occupant's cache/state (stale KV positions, carried SSM state)."""
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, slots=1, cache_len=64)
    eng.submit(Request(0, [5, 6, 7, 8, 9], max_new=4))   # longer, different
    eng.submit(Request(1, [1, 2, 3], max_new=4))         # reuses slot 0
    done = {r.req_id: r.generated for r in eng.run()}
    import jax.numpy as jnp
    ref = generate(params, cfg, jnp.asarray([[1, 2, 3]], jnp.int32),
                   max_new=4)[0, 3:].tolist()
    assert done[1] == ref, (done[1], ref)


def test_swa_ring_decode_beyond_window():
    """Gemma-style sliding-window layers stay correct once the ring wraps."""
    cfg = get_smoke_config("gemma3-12b")  # window 64
    params = init_params(jax.random.PRNGKey(5), cfg)
    B, S = 1, 100   # > window
    toks = jax.random.randint(jax.random.PRNGKey(6), (B, S), 0,
                              cfg.vocab_size)
    ref, _, _ = forward(params, cfg, {"tokens": toks})
    from repro.models.transformer import init_cache
    caches = init_cache(cfg, B, S)
    pos = jnp.arange(80, dtype=jnp.int32)[None]
    _, _, caches = forward(params, cfg, {"tokens": toks[:, :80]},
                           caches=caches, positions=pos)
    errs = []
    for t in range(80, S):
        ld, _, caches = forward(params, cfg, {"tokens": toks[:, t:t + 1]},
                                caches=caches,
                                positions=jnp.full((B, 1), t, jnp.int32),
                                decode=True)
        errs.append(float(jnp.abs(ld[:, 0] - ref[:, t]).max()))
    assert max(errs) < 0.05, max(errs)
