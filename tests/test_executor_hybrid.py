"""Decentralized executor over a HYBRID architecture's DAG (mamba + attn
+ MoE blocks): the op-vocabulary/IR decoupling (paper P5) must hold for
non-transformer families too."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.dag import build_model_dag
from repro.core.decomposer import decompose_contiguous
from repro.core.executor import LocalCluster


@pytest.mark.parametrize(
    "arch",
    [pytest.param("jamba-1.5-large-398b", marks=pytest.mark.xfail(
        strict=False,
        reason="known seed failure: MoE train step — no differentiation "
               "rule for optimization_barrier in the EP dispatch (ROADMAP "
               "'Known seed failures'); inference/serving unaffected")),
     "rwkv6-7b"])
def test_hybrid_pipeline_training(arch):
    cfg = get_smoke_config(arch)
    if cfg.n_experts:  # avoid capacity-drop nondeterminism across partitions
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    B, S = 2, 16
    dag = build_model_dag(cfg, batch=B, seq=S, kind="train")
    # hybrid DAG carries mamba_block/rwkv_block/moe_ffn ops
    ops = {dag[n].op for n in dag.topo_order()}
    if arch.startswith("jamba"):
        assert "mamba_block" in ops and "moe_ffn" in ops and "attn_block" in ops
    else:
        assert "rwkv_block" in ops

    toks = jax.random.randint(jax.random.PRNGKey(0), (B, S), 0,
                              cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    key = jax.random.PRNGKey(7)
    c1 = LocalCluster(dag, decompose_contiguous(dag, 1), cfg, key)
    c3 = LocalCluster(dag, decompose_contiguous(dag, 3), cfg, key)
    allp = {}
    for ex in c1.executors:
        allp.update(ex.params)
    for ex in c3.executors:
        ex.params = {k: allp[k] for k in ex.params}
    l1 = c1.train_step(toks, labels)
    l3 = c3.train_step(toks, labels)
    assert l1 == l3, (l1, l3)
    l1b = c1.train_step(toks, labels)
    assert l1b == c3.train_step(toks, labels)
    assert np.isfinite(l1b) and l1b != l1
