"""FusionAI core unit + property tests: DAG IR, decomposer, scheduler
(Eq. 2), perf model, pipeline closed forms (Eqs. 3-4), broker fault
tolerance, DHT, compression invariants."""
import json
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback, see the shim
    from _hypothesis_shim import given, settings, st

from repro.configs import get_config, get_smoke_config
from repro.core.broker import Broker
from repro.core.compression import (CompressionSpec, ErrorFeedback,
                                    int8_block_decode, int8_block_encode,
                                    qsgd_bytes, qsgd_decode, qsgd_encode,
                                    topk_bytes, topk_decode, topk_encode)
from repro.core.dag import DAG, OpNode, build_model_dag
from repro.core.decomposer import (assignment_of, decompose_by_memory,
                                   decompose_contiguous, part_stats)
from repro.core.dht import DHT
from repro.core.perfmodel import (DEVICE_CATALOG, LINK_REGIMES, CompNode,
                                  LinkSpec, PerfModel, fit_lambda, make_fleet)
from repro.core.pipeline import (StageTimes, bubble_fraction, estimate_system,
                                 latency_eq3, pipelined_eq4, simulate_pipeline,
                                 stage_times)
from repro.core.scheduler import (Task, schedule_loadbalance,
                                  schedule_pipeline, tasks_from_parts)


# ---------------------------------------------------------------------------
# DAG IR
# ---------------------------------------------------------------------------

def test_dag_build_and_table3_attrs():
    dag = build_model_dag(get_config("bert-large"), batch=8, seq=128)
    dag.validate()
    # Fig.4 granularity: embed + 24x(attn, ffn) + head + input/label/loss
    assert len(dag) == 3 + 1 + 24 * 2 + 1
    parts = decompose_contiguous(dag, 3)
    assignment = assignment_of(parts)
    attrs = dag.subgraph_attrs(assignment)
    # every cut edge appears as outwards on the producer side and outer on
    # the consumer side (Table 3 consistency)
    for k, g in attrs.items():
        for name in g["outwards"]:
            users = {assignment[u] for u in dag.users(name)}
            assert users - {k}, name
    # cut bytes positive and equal to bus-level accounting base
    assert dag.cut_bytes(assignment) > 0


def test_dag_json_roundtrip():
    dag = build_model_dag(get_smoke_config("gpt3-24l"), batch=2, seq=8)
    dag2 = DAG.from_json(dag.to_json())
    assert dag2.topo_order() == dag.topo_order()
    assert dag2.total_flops() == dag.total_flops()
    assert dag2.edges() == dag.edges()


def test_dag_rejects_non_topological():
    dag = DAG()
    with pytest.raises(AssertionError):
        dag.add(OpNode("a", "x", args=("missing",)))


# ---------------------------------------------------------------------------
# Decomposer
# ---------------------------------------------------------------------------

@given(st.integers(2, 40), st.integers(1, 8), st.integers(0, 10**6))
@settings(max_examples=50, deadline=None)
def test_decompose_contiguous_properties(n_ops, k, seed):
    rng = np.random.RandomState(seed)
    dag = DAG()
    prev = None
    for i in range(n_ops):
        dag.add(OpNode(f"op{i}", "x", args=(prev,) if prev else (),
                       flops=float(rng.randint(1, 100))))
        prev = f"op{i}"
    parts = decompose_contiguous(dag, k)
    # contiguous cover, no overlap
    flat = [n for p in parts for n in p]
    assert flat == dag.topo_order()
    # min-max optimality vs brute bound: max part <= total (trivial) and
    # >= total/k (pigeonhole)
    w = {n: dag[n].flops for n in dag.topo_order()}
    maxpart = max(sum(w[n] for n in p) for p in parts)
    total = sum(w.values())
    assert maxpart >= total / len(parts) - 1e-9
    # DP optimality: no single-boundary shift reduces the GLOBAL max
    sums = [sum(w[n] for n in p) for p in parts]
    for i in range(len(parts) - 1):
        others = [s for j, s in enumerate(sums) if j not in (i, i + 1)]
        base = max(others) if others else 0.0
        a, b = sums[i], sums[i + 1]
        if len(parts[i]) > 1:
            m = w[parts[i][-1]]
            assert maxpart <= max(base, a - m, b + m) + 1e-9
        if len(parts[i + 1]) > 1:
            m = w[parts[i + 1][0]]
            assert maxpart <= max(base, a + m, b - m) + 1e-9


def test_decompose_by_memory_respects_budget():
    cfg = get_config("bert-large")
    dag = build_model_dag(cfg, batch=8, seq=128)
    limit = dag.total_param_bytes() / 10
    parts = decompose_by_memory(dag, [limit])
    for p in parts:
        used = sum(dag[n].param_bytes for n in p)
        assert used <= limit or len(p) == 1


def test_decompose_speed_aware():
    """Faster peers get proportionally more FLOPs."""
    cfg = get_config("bert-large")
    dag = build_model_dag(cfg, batch=8, seq=128)
    parts = decompose_contiguous(dag, 2, speeds=[3.0, 1.0])
    stats = part_stats(dag, parts)
    assert stats[0]["flops"] > stats[1]["flops"]


# ---------------------------------------------------------------------------
# Scheduler (Eq. 2)
# ---------------------------------------------------------------------------

@given(st.integers(1, 20), st.integers(1, 6), st.integers(0, 10**6))
@settings(max_examples=40, deadline=None)
def test_schedule_loadbalance_properties(n_tasks, n_nodes, seed):
    rng = np.random.RandomState(seed)
    tasks = [Task(i, (), flops=float(rng.randint(1, 1000)) * 1e9,
                  gpu_bytes=float(rng.randint(1, 4)) * 1e9)
             for i in range(n_tasks)]
    nodes = make_fleet([("rtx3080", n_nodes)], LINK_REGIMES["wan_1gbps"])
    sched = schedule_loadbalance(tasks, nodes)
    assert set(sched.assignment) == {t.task_id for t in tasks}
    # makespan >= both trivial lower bounds
    speeds = sum(n.speed for n in nodes)
    lb = max(max(t.flops for t in tasks) / nodes[0].speed,
             sum(t.flops for t in tasks) / speeds)
    if sched.feasible:
        assert sched.makespan >= lb - 1e-9
        # LPT on identical machines is within 4/3 of OPT; allow slack for
        # the memory constraints
        assert sched.makespan <= 2.0 * lb + max(
            t.flops for t in tasks) / nodes[0].speed


def test_schedule_memory_constraint_enforced():
    node = CompNode(0, DEVICE_CATALOG["rtx3080"], LINK_REGIMES["wan_1gbps"])
    big = Task(0, (), flops=1e9, gpu_bytes=9e9)
    small = Task(1, (), flops=1e9, gpu_bytes=2e9)
    sched = schedule_loadbalance([big, small], [node])
    assert not sched.feasible  # 11GB > 10GB of a 3080


# ---------------------------------------------------------------------------
# Perf model
# ---------------------------------------------------------------------------

def test_fit_lambda_recovers_scaling():
    peak = 59.5e12
    lam_true = 0.63
    flops = [1e12, 2e12, 5e12]
    times = [f / (peak * lam_true) for f in flops]
    lam = fit_lambda(flops, times, peak)
    assert abs(lam - lam_true) < 1e-6


def test_alpha_beta_link():
    link = LinkSpec.from_bandwidth(125e6, 0.02)  # 1 Gbps, 20ms
    assert abs(link.time(125e6) - 1.02) < 1e-9
    assert link.time(0) == 0.0


def test_op_time_eq1_terms():
    nodes = make_fleet([("rtx3080", 2)], LINK_REGIMES["wan_1gbps"], lam=1.0)
    pm = PerfModel(nodes)
    op = OpNode("f", "x", args=("p",), flops=59.5e12, out_bytes=0.0)
    # same-peer: R=0 -> exactly 1 second of compute
    t_local = pm.op_time(op, 0, {"p": 0}, {"p": 1e9})
    assert abs(t_local - 1.0) < 1e-6
    # remote parent adds alpha + beta*M
    t_remote = pm.op_time(op, 0, {"p": 1}, {"p": 125e6})
    assert t_remote > t_local + 1.0  # 1 Gbps for 125MB + latency ≈ 1s+


# ---------------------------------------------------------------------------
# Pipeline (Eqs. 3-4) + simulator
# ---------------------------------------------------------------------------

def test_eq4_exact_when_no_comm():
    st_ = StageTimes(compute=[1.0, 2.0, 1.5], receive=[0.0, 0.0, 0.0])
    nb = 10
    assert abs(simulate_pipeline(st_, nb) - pipelined_eq4(st_, nb)) < 1e-9


@given(st.lists(st.floats(0.1, 5.0), min_size=1, max_size=8),
       st.lists(st.floats(0.0, 3.0), min_size=1, max_size=8),
       st.integers(1, 64))
@settings(max_examples=60, deadline=None)
def test_pipeline_sim_bounds(cs, rs, nb):
    n = min(len(cs), len(rs))
    st_ = StageTimes(compute=cs[:n], receive=rs[:n])
    sim = simulate_pipeline(st_, nb)
    lat = latency_eq3(st_)
    eq4 = pipelined_eq4(st_, nb)
    assert sim >= lat - 1e-9                       # first batch must traverse
    # with serialized links (the paper's model) Eq. 4 is exact
    assert abs(sim - eq4) < 1e-6 * max(1.0, eq4)


def test_estimate_system_bert():
    cfg = get_config("bert-large")
    dag = build_model_dag(cfg, batch=32, seq=512, kind="inference")
    nodes = make_fleet([("rtx3080", 50)], LINK_REGIMES["wan_1gbps"], lam=1.0)
    pm = PerfModel(nodes)
    est = estimate_system(dag, pm, [n.node_id for n in nodes], n_batches=512,
                          batch_size=32)
    assert est["n_stages"] <= 50
    assert est["latency_s"] > 0
    assert est["throughput_samples_s"] > 0
    assert 0 <= est["bubble_fraction"] <= 1


# ---------------------------------------------------------------------------
# Broker + DHT
# ---------------------------------------------------------------------------

def _register_fleet(broker, n=20, reliability=0.95):
    for node in make_fleet([("rtx3080", n)], LINK_REGIMES["wan_1gbps"]):
        node.reliability = reliability
        broker.register(node)


def test_broker_backup_pool_replacement():
    broker = Broker(backup_fraction=0.3, seed=1)
    _register_fleet(broker, 20)
    assert len(broker.backup) >= 3
    dag = build_model_dag(get_config("bert-large"), batch=8, seq=128)
    sched = broker.submit_job(dag, n_parts=8)
    assert sched.feasible
    victim = next(iter({nid for nid in sched.assignment.values()}))
    n_backup_before = len(broker.backup)
    broker.quit(victim, graceful=False)
    assert len(broker.backup) == n_backup_before - 1      # one drafted
    # the victim's tasks were remapped to the replacement
    assert victim not in set(broker.schedule.assignment.values())


def test_broker_sim_deterministic_and_recovers():
    results = []
    for _ in range(2):
        broker = Broker(backup_fraction=0.25, seed=42)
        _register_fleet(broker, 30, reliability=0.9)
        dag = build_model_dag(get_config("bert-large"), batch=8, seq=128)
        broker.submit_job(dag, n_parts=10)
        results.append(broker.run_sim(rounds=20))
    assert results[0] == results[1]                        # seeded determinism
    assert results[0]["all_tasks_assigned"]
    assert results[0]["failures"] > 0                      # sim actually fails nodes


def test_dht_replication_and_churn():
    dht = DHT(range(8), replication=3)
    for i in range(50):
        dht.put(f"key{i}", i)
    # single node loss cannot lose data at replication 3
    dht.leave(3)
    assert all(dht.get(f"key{i}") == i for i in range(50))
    dht.rebalance()
    dht.leave(5)
    dht.leave(0)
    assert all(dht.get(f"key{i}") == i for i in range(50))
    # new node join serves lookups after rebalance
    dht.join(99)
    dht.rebalance()
    assert all(dht.get(f"key{i}") == i for i in range(50))


# ---------------------------------------------------------------------------
# Compression (§2.3)
# ---------------------------------------------------------------------------

@given(st.integers(10, 500), st.floats(0.01, 0.5), st.integers(0, 10**6))
@settings(max_examples=30, deadline=None)
def test_topk_properties(n, ratio, seed):
    import jax
    import jax.numpy as jnp
    g = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    vals, idx = topk_encode(g, ratio)
    dec = topk_decode(vals, idx, g.shape)
    k = max(1, int(n * ratio))
    # decoded tensor preserves exactly k entries, all from g
    nz = np.nonzero(np.asarray(dec))[0]
    assert len(nz) <= k
    np.testing.assert_allclose(np.asarray(dec)[nz], np.asarray(g)[nz])
    # kept magnitudes dominate dropped ones
    if k < n:
        kept_min = np.abs(np.asarray(vals)).min()
        dropped = np.delete(np.asarray(g), np.asarray(idx))
        assert kept_min >= np.abs(dropped).max() - 1e-6
    assert topk_bytes(n, ratio) <= 8 * k


def test_qsgd_unbiased_and_bounded():
    import jax
    import jax.numpy as jnp
    g = jax.random.normal(jax.random.PRNGKey(0), (2000,))
    decs = []
    for i in range(64):
        codes, scale = qsgd_encode(jax.random.PRNGKey(i), g, levels=16)
        decs.append(np.asarray(qsgd_decode(codes, scale, levels=16)))
    mean = np.stack(decs).mean(0)
    step = float(scale) / 15
    # unbiasedness: empirical mean within a few std errors of g
    assert np.abs(mean - np.asarray(g)).max() < 4 * step
    assert qsgd_bytes(2000, 16) < 8000


def test_error_feedback_accumulates_everything():
    import jax
    import jax.numpy as jnp
    ef = ErrorFeedback(ratio=0.1)
    g = jax.random.normal(jax.random.PRNGKey(1), (100,))
    res = ef.init(g)
    sent_total = np.zeros(100)
    for _ in range(50):
        sent, res = ef.step(g, res)
        sent_total += np.asarray(sent)
    # EF property: total sent ~ T*g (residual bounded)
    assert np.abs(sent_total / 50 - np.asarray(g)).max() < np.abs(
        np.asarray(g)).max()


def test_int8_block_roundtrip_bound():
    import jax
    x = jax.random.normal(jax.random.PRNGKey(2), (1000,)) * 3
    q, s = int8_block_encode(x, block=128)
    dec = int8_block_decode(q, s, x.shape)
    err = np.abs(np.asarray(dec) - np.asarray(x))
    assert err.max() <= float(np.abs(np.asarray(x)).max()) / 127 + 1e-6


def test_compression_spec_pricing_monotone():
    n = 10**6
    raw = CompressionSpec("none").bytes(n)
    assert CompressionSpec("topk", ratio=0.01).bytes(n) < raw / 10
    assert CompressionSpec("int8").bytes(n) < raw / 3
    assert CompressionSpec("local_sgd", period=8).bytes(n) == raw / 8
