"""Stateful failover: verified KV page migration + decode-state snapshots.

What must hold (and is pinned here):

* ``export_state`` / ``import_state`` move a request mid-decode between
  engines with BITWISE-identical greedy output to an uninterrupted run —
  page contents, positions, sampling params and recurrent carries all
  ride the payload, and the destination re-runs no prefill;
* the chained-crc32 verification is all-or-nothing: one flipped byte
  anywhere in the payload (or a lying checksum field) rejects the whole
  transfer BEFORE anything lands in the destination pool, leaving the
  destination engine exactly as it was;
* import deduplicates full prompt-prefix pages already resident in the
  destination's content registry — only non-resident pages transfer;
* the router's migrate-vs-reprefill decision follows bytes over
  bandwidth: fast links migrate, slow WAN links re-prefill;
* crash recovery via router snapshots re-prefills prompt + snapshot
  tokens in ONE extended admission and re-decodes only what came after
  the last snapshot — still bitwise-equal for greedy decode;
* the LRU hold keeps refcount-zero registered pages attachable across
  idle gaps, revives them on re-share, and gives them up FIRST under
  reservation demand and ``pool_pressure``;
* ``FaultPlan.at`` hands out copies — the schedule cannot be mutated
  through its own accessor.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.perfmodel import LINK_REGIMES
from repro.models.transformer import init_params
from repro.serve.engine import (BlockAllocator, Request, ServingEngine,
                                generate)
from repro.serve.faults import FAULT_KINDS, Fault, FaultPlan
from repro.serve.router import FleetRouter, sim_node

PROMPT = list(range(2, 40))
MAX_NEW = 12
_cache: dict = {}


def _tiny():
    if "params" not in _cache:
        cfg = dataclasses.replace(get_smoke_config("gpt3-24l"),
                                  vocab_size=128, d_model=128, d_ff=256,
                                  n_heads=4, n_kv_heads=4, head_dim=32)
        _cache["cfg"] = cfg
        _cache["params"] = init_params(jax.random.PRNGKey(0), cfg)
    return _cache["params"], _cache["cfg"]


def _engine(slots=2, cache_len=64, **kw):
    params, cfg = _tiny()
    return ServingEngine(params, cfg, slots=slots, cache_len=cache_len,
                         chunk=8, paged=True, page_size=16, **kw)


def _ref(prompt=None, max_new=MAX_NEW):
    prompt = PROMPT if prompt is None else prompt
    key = (tuple(prompt), max_new)
    if key not in _cache.setdefault("refs", {}):
        params, cfg = _tiny()
        _cache["refs"][key] = generate(
            params, cfg, jnp.asarray([prompt], jnp.int32),
            max_new=max_new)[0, len(prompt):].tolist()
    return _cache["refs"][key]


def _export_mid_decode(src, req, ticks=5):
    src.submit(req)
    for _ in range(ticks):
        src.tick()
    assert req.generated, "request must be mid-decode before export"
    return src.export_state(req)


def _flip_first_pool_byte(state):
    for key in sorted(state.pool):
        arr = np.ascontiguousarray(state.pool[key]).copy()
        if arr.nbytes:
            arr.view(np.uint8).reshape(-1)[0] ^= 0xFF
            state.pool[key] = arr
            return
    raise AssertionError("no pool payload to corrupt")


# ---------------------------------------------------------------------------
# Satellite regressions: FaultPlan.at copy, corrupt fault validation
# ---------------------------------------------------------------------------

def test_fault_plan_at_returns_copy():
    plan = FaultPlan([Fault(3, 0, "crash"), Fault(3, 1, "straggle")])
    got = plan.at(3)
    assert [f.kind for f in got] == ["crash", "straggle"]
    got.clear()                      # caller mangles its copy...
    got.append("junk")
    assert [f.kind for f in plan.at(3)] == ["crash", "straggle"]
    assert len(plan) == 2            # ...the schedule is untouched


def test_corrupt_fault_kind():
    assert "corrupt" in FAULT_KINDS
    f = Fault(0, 1, "corrupt", duration=3)
    assert f.duration == 3
    with pytest.raises(ValueError):
        Fault(0, 1, "corrupt", duration=0)
    # seeded plans can draw corrupt faults
    plan = FaultPlan.seeded(7, ticks=200, replica_ids=[0, 1], rate=0.3)
    assert any(f.kind == "corrupt" for f in plan)


# ---------------------------------------------------------------------------
# Allocator: LRU hold on refcount-zero registered pages
# ---------------------------------------------------------------------------

def test_lru_hold_keeps_and_revives_pages():
    a = BlockAllocator(4, hold_limit=2)
    assert a.reserve(2)
    b0, b1 = a.alloc_one(), a.alloc_one()
    assert a.register(101, (None, (1,)), b0)
    assert a.register(102, (b0, (2,)), b1)
    assert a.free([b0]) == []        # registered + hold: NOT scrubbed
    assert a.free([b1]) == []
    assert a.n_held == 2 and a.n_free + a.n_held == 4
    # the held page is still attachable: share revives it to refcount 1
    assert a.lookup(101, (None, (1,))) == b0
    a.share(b0)
    assert a.refcount[b0] == 1 and a.n_held == 1
    assert a.free([b0]) == []        # back to held again
    assert a.n_held == 2


def test_lru_hold_evicts_oldest_under_demand():
    a = BlockAllocator(4, hold_limit=4)
    assert a.reserve(4)
    blocks = [a.alloc_one() for _ in range(4)]
    for i, b in enumerate(blocks):
        assert a.register(200 + i, (None, (i,)), b)
        assert a.free([b]) == []
    assert a.n_held == 4
    # a fresh reservation needs real free pages: oldest holds evicted
    # first, deregistered, and queued for scrubbing
    assert a.reserve(3)
    assert a.n_held == 1
    assert a.lookup(200, (None, (0,))) is None         # evicted
    assert a.lookup(203, (None, (3,))) == blocks[3]    # newest kept
    assert sorted(a.take_scrub()) == sorted(blocks[:3])
    assert a.take_scrub() == []      # drained


def test_pool_pressure_evicts_holds_first():
    eng = _engine(hold_pages=8)
    eng.submit(Request(0, list(PROMPT), max_new=2))
    eng.run()
    held = eng._alloc.n_held
    assert held >= 2                 # finished request's pages held
    eng.set_pool_pressure(held)
    assert eng._alloc.n_held == 0    # holds gave way before the pool did
    assert eng._alloc.withheld == held
    eng.set_pool_pressure(0)
    assert eng._alloc.n_free == eng.num_blocks


# ---------------------------------------------------------------------------
# Tentpole: export/import round trip
# ---------------------------------------------------------------------------

def test_migration_round_trip_bitwise():
    src, dst = _engine(), _engine()
    req = Request(1, list(PROMPT), max_new=MAX_NEW)
    state = _export_mid_decode(src, req)
    assert state is not None and state.payload_bytes > 0
    # the source slot is fully released — nothing leaks
    assert src.n_active == 0
    assert src._alloc.reserved == 0 and not src._alloc.refcount
    assert dst.import_state(state)
    out = dst.run()
    assert len(out) == 1 and out[0] is req
    assert req.generated == _ref()
    # the destination re-ran NO prefill: the whole point of migrating
    assert dst.stats["prefill_calls"] == 0
    assert dst.stats["imported"] == 1
    assert src.stats["exported"] == 1


def test_migration_preserves_rep_penalty_state():
    # greedy + repetition penalty is deterministic AND stateful: the
    # per-slot seen-token mask must ride the migration for the
    # destination's decode to match an uninterrupted run
    src, dst = _engine(), _engine()
    kw = dict(max_new=MAX_NEW, rep_penalty=1.3)
    ref_eng = _engine()
    ref_eng.submit(Request(0, list(PROMPT), **kw))
    ref_out = ref_eng.run()[0].generated
    req = Request(1, list(PROMPT), **kw)
    state = _export_mid_decode(src, req)
    assert dst.import_state(state)
    assert dst.run()[0].generated == ref_out


def test_import_rejects_flipped_byte():
    src, dst = _engine(), _engine()
    req = Request(2, list(PROMPT), max_new=MAX_NEW)
    state = _export_mid_decode(src, req, ticks=4)
    _flip_first_pool_byte(state)
    assert not dst.import_state(state)
    # rejection is clean: no slot taken, no pages reserved or written
    assert dst.stats["import_rejects"] == 1
    assert dst.n_active == 0
    assert dst._alloc.reserved == 0
    assert dst._alloc.n_free == dst.num_blocks


def test_import_rejects_checksum_lie():
    src, dst = _engine(), _engine()
    req = Request(3, list(PROMPT), max_new=MAX_NEW)
    state = _export_mid_decode(src, req, ticks=4)
    state.checksum ^= 1
    assert not dst.import_state(state)
    assert dst.stats["import_rejects"] == 1


def test_import_refuses_fingerprint_mismatch():
    src = _engine()
    other_geometry = _engine(cache_len=96)     # different page budget
    req = Request(4, list(PROMPT), max_new=MAX_NEW)
    state = _export_mid_decode(src, req, ticks=3)
    assert not other_geometry.import_state(state)
    # incompatibility is not a verification failure
    assert other_geometry.stats["import_rejects"] == 0
    assert other_geometry.n_active == 0


def test_import_dedups_resident_prefix_pages():
    src = _engine()
    req = Request(5, list(PROMPT), max_new=MAX_NEW)
    state = _export_mid_decode(src, req, ticks=3)
    # destination already served (and LRU-holds) the same prompt
    dst = _engine(hold_pages=8)
    dst.submit(Request(6, list(PROMPT), max_new=2))
    dst.run()
    assert dst.import_state(state)
    assert dst.stats["deduped_pages"] >= 2
    assert dst.run()[-1].generated == _ref()


def test_snapshot_resume_admission_bitwise():
    ref_out = _ref()
    eng = _engine()
    req = Request(7, list(PROMPT), max_new=MAX_NEW,
                  resume_tokens=ref_out[:5])
    eng.submit(req)
    out = eng.run()
    assert out[0].generated == ref_out
    assert eng.stats["resumed_tokens"] == 5


# ---------------------------------------------------------------------------
# Router: migration on soft-drain / rebalance, corrupt fallback, snapshots
# ---------------------------------------------------------------------------

def _straggle_fleet(plan, migration="auto", slots=4):
    return FleetRouter([(_engine(slots=slots), "rtx4090"),
                        (_engine(slots=slots), "rtx3080")],
                       fault_plan=plan, migration=migration)


def test_soft_drain_migrates_with_zero_retries():
    plan = FaultPlan([Fault(2, 0, "straggle", factor=8.0, duration=10)])
    router = _straggle_fleet(plan)
    reqs = [Request(i, [3 + i] * 20, max_new=10) for i in range(3)]
    for r in reqs:
        router.submit(r)
    res = router.run(max_ticks=300)
    assert router.stats["soft_drains"] >= 1
    assert router.stats["migrations"] >= 1
    moved = [r for r in res.completed
             if len(router.placements[r.req_id]) > 1]
    assert moved
    for r in moved:
        assert r.retries == 0        # migration costs no retry budget
    for r in res.completed:
        assert r.generated == _ref(r.prompt, r.max_new)


def test_migration_never_restores_requeue():
    plan = FaultPlan([Fault(2, 0, "straggle", factor=8.0, duration=10)])
    router = _straggle_fleet(plan, migration="never")
    reqs = [Request(i, [3 + i] * 20, max_new=10) for i in range(3)]
    for r in reqs:
        router.submit(r)
    res = router.run(max_ticks=300)
    assert router.stats["migrations"] == 0
    victims = [r for r in res.completed if r.retries > 0]
    assert victims                   # old semantics: drain = requeue
    for r in res.completed:
        assert r.generated == _ref(r.prompt, r.max_new)


def test_corrupt_transfer_rejected_victim_bitwise():
    plan = FaultPlan([Fault(0, 0, "corrupt", duration=300),
                      Fault(2, 0, "straggle", factor=8.0, duration=10)])
    router = _straggle_fleet(plan)
    reqs = [Request(i, [3 + i] * 20, max_new=10) for i in range(3)]
    for r in reqs:
        router.submit(r)
    res = router.run(max_ticks=300)
    assert router.stats["corrupt_faults"] == 1
    assert router.stats["soft_drains"] >= 1
    # every flipped payload was rejected by the checksum chain and fell
    # back to requeue-from-prompt — no migration ever succeeded
    assert router.stats["migrations"] == 0
    assert router.stats["migration_fallbacks"] >= 1
    rejects = sum(r.engine.stats["import_rejects"] for r in router.replicas)
    assert rejects >= 1
    assert sorted(r.req_id for r in res.completed) == [0, 1, 2]
    for r in res.completed:
        assert r.generated == _ref(r.prompt, r.max_new)


def test_crash_snapshot_restores_decoded_tokens():
    plan = FaultPlan([Fault(14, 0, "crash")])
    router = FleetRouter([(_engine(slots=4, cache_len=96), "rtx4090")],
                         standby=[(_engine(slots=4, cache_len=96),
                                   "rtx4090")],
                         fault_plan=plan, snapshot_every=4)
    reqs = [Request(i, [3 + i] * 20, max_new=40) for i in range(2)]
    for r in reqs:
        router.submit(r)
    res = router.run(max_ticks=500)
    assert router.stats["failures"] == 1
    assert router.stats["snapshot_restores"] >= 1
    resumed = sum(r.engine.stats["resumed_tokens"] for r in router.replicas)
    assert resumed >= 1              # tokens-so-far came back via snapshot
    for r in res.completed:
        assert r.generated == _ref(r.prompt, r.max_new)


def test_rebalance_migrates_newest_off_loaded_replica():
    e0, e1 = _engine(slots=4, cache_len=96), _engine(slots=4, cache_len=96)
    router = FleetRouter([(e0, "rtx4090"), (e1, "rtx4090")],
                         rebalance_every=2, rebalance_factor=1.5)
    reqs = [Request(i, [3 + i] * 20, max_new=16) for i in range(3)]
    for r in reqs:
        e0.submit(r)                 # skew: all load on replica 0
    res = router.run(max_ticks=400)
    assert router.stats["rebalances"] >= 1
    assert router.stats["migrations"] >= 1
    for r in res.completed:
        assert r.generated == _ref(r.prompt, r.max_new)


def test_migrate_cost_decision_follows_link_speed():
    plan = FaultPlan([Fault(2, 0, "straggle", factor=8.0, duration=10)])
    reqs = lambda: [Request(i, [3 + i] * 20, max_new=10) for i in range(3)]

    # LAN: payload bytes are cheap -> migrate
    lan = FleetRouter(
        [(_engine(slots=4), sim_node("rtx4090",
                                     link=LINK_REGIMES["lan_10gbps"])),
         (_engine(slots=4), sim_node("rtx3080",
                                     link=LINK_REGIMES["lan_10gbps"]))],
        fault_plan=plan)
    for r in reqs():
        lan.submit(r)
    lan_res = lan.run(max_ticks=300)
    assert lan.stats["migrations"] >= 1

    # 10 Mbps WAN: shipping pages loses to re-prefilling -> fall back
    plan = FaultPlan([Fault(2, 0, "straggle", factor=8.0, duration=10)])
    wan = FleetRouter(
        [(_engine(slots=4), sim_node("rtx4090",
                                     link=LINK_REGIMES["wan_10mbps"])),
         (_engine(slots=4), sim_node("rtx3080",
                                     link=LINK_REGIMES["wan_10mbps"]))],
        fault_plan=plan)
    for r in reqs():
        wan.submit(r)
    wan_res = wan.run(max_ticks=300)
    assert wan.stats["migrations"] == 0
    # either way nothing is lost and survivors stay bitwise-equal
    for res in (lan_res, wan_res):
        assert sorted(r.req_id for r in res.completed) == [0, 1, 2]
        for r in res.completed:
            assert r.generated == _ref(r.prompt, r.max_new)
