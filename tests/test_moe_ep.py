"""Expert-parallel MoE (shard_map) must equal the single-device reference
in forward and gradients, on a real 2x2 device mesh (subprocess so the
512-device dry-run flags never leak here)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import adamw


EP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models.layers import moe_init, moe_apply
from repro.models.moe_ep import moe_apply_ep

mesh = jax.make_mesh((2, 2), ("data", "model"))
for arch in ["qwen3-moe-235b-a22b", "deepseek-v3-671b"]:
    cfg = dataclasses.replace(get_smoke_config(arch), capacity_factor=8.0)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.bfloat16)
    ref, aux_ref = moe_apply(p, x, cfg)
    ep = jax.jit(lambda p, x: moe_apply_ep(p, x, cfg, mesh, ("data",),
                                           "model"))
    out, aux = ep(p, x)
    err = float(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)).max())
    assert err < 1e-2, (arch, err)
    assert abs(float(aux) - float(aux_ref)) < 1e-6, arch
    g = jax.jit(jax.grad(lambda p: (ep(p, x)[0].astype(jnp.float32) ** 2).mean()))(p)
    gr = jax.grad(lambda p: (moe_apply(p, x, cfg)[0].astype(jnp.float32) ** 2).mean())(p)
    gerr = max(float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
               for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(gr)))
    assert gerr < 1e-3, (arch, gerr)
    print(f"{arch}: fwd {err:.2e} grad {gerr:.2e} OK")
print("EP_OK")
"""


@pytest.mark.slow
@pytest.mark.xfail(strict=False, reason="known seed failure: MoE-EP subprocess parity (ROADMAP 'Known seed failures')")
def test_moe_ep_matches_reference_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", EP_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "EP_OK" in r.stdout, r.stderr[-3000:]


def test_adamw_8bit_state_smaller_and_converges():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (64, 64)) * 0.1
    target = jax.random.normal(jax.random.PRNGKey(1), (64, 64))

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    for bits in (32, 8):
        opt = adamw(5e-2, state_bits=bits, clip_norm=None)
        params = {"w": w}
        state = opt.init(params)
        for _ in range(60):
            g = jax.grad(loss)(params)
            params, state, _ = opt.update(g, state, params)
        final = float(loss(params))
        assert final < 0.05, (bits, final)
        if bits == 8:
            mu_bytes = sum(l.dtype.itemsize * l.size
                           for l in jax.tree.leaves(state["mu"]))
            assert mu_bytes < 64 * 64 * 4 / 2  # int8 + per-row scales < f32/2


def test_adamw_8bit_matches_fp32_early():
    """First steps of 8-bit Adam track fp32 Adam closely (moments are
    near-zero so quantization error is small)."""
    key = jax.random.PRNGKey(2)
    params = {"w": jax.random.normal(key, (32, 128)) * 0.1}
    g = {"w": jax.random.normal(jax.random.PRNGKey(3), (32, 128)) * 0.01}
    outs = {}
    for bits in (32, 8):
        opt = adamw(1e-3, state_bits=bits, clip_norm=None)
        st = opt.init(params)
        p = params
        for _ in range(3):
            p, st, _ = opt.update(g, st, p)
        outs[bits] = p["w"]
    # int8 moments track within quantization precision: err bounded by a
    # fraction of the applied update (|Δ| ≈ 3·lr here)
    err = float(jnp.abs(outs[32] - outs[8]).max())
    applied = float(jnp.abs(outs[32] - params["w"]).max())
    assert err < 0.35 * applied, (err, applied)
