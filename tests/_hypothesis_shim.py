"""Tiny deterministic stand-in for ``hypothesis`` so the property tests
in ``test_core.py`` still collect and run on a bare environment.

Only the surface used by the test suite is implemented: ``given`` over
positional strategies, ``settings(max_examples=..., deadline=...)`` and
the ``st.integers`` / ``st.floats`` / ``st.lists`` strategies.  Each
example draws from a seeded ``numpy.random.RandomState`` so failures
reproduce exactly; install real ``hypothesis`` (requirements-dev.txt)
for shrinking and broader search.
"""
from __future__ import annotations


import types

import numpy as np

# keep bare-env runs quick; real hypothesis honours the full request
_MAX_EXAMPLES_CAP = 15


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _integers(lo: int, hi: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.randint(lo, hi + 1)))


def _floats(lo: float, hi: float) -> _Strategy:
    return _Strategy(lambda rng: float(rng.uniform(lo, hi)))


def _lists(elem: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    return _Strategy(lambda rng: [
        elem.draw(rng) for _ in range(int(rng.randint(min_size, max_size + 1)))])


st = types.SimpleNamespace(integers=_integers, floats=_floats, lists=_lists)


def settings(max_examples: int = 20, **_ignored):
    """Records the example budget on the wrapped function."""
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strategies):
    def deco(fn):
        # zero-arg wrapper WITHOUT functools.wraps: pytest must not see the
        # wrapped function's parameters (it would resolve them as fixtures)
        def run():
            n = getattr(run, "_max_examples", None) \
                or getattr(fn, "_max_examples", 20)
            for i in range(min(n, _MAX_EXAMPLES_CAP)):
                rng = np.random.RandomState(i)
                drawn = [s.draw(rng) for s in strategies]
                try:
                    fn(*drawn)
                except Exception as e:  # noqa: BLE001 — annotate the example
                    raise AssertionError(
                        f"falsifying example (shim, seed={i}): {drawn!r}"
                    ) from e
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        return run
    return deco
