"""Prefix-sharing paged cache: content-addressed block reuse, refcounts,
copy-on-write.

What must hold (and is pinned here):

* greedy decode stays BITWISE-identical to the non-shared paged engine —
  sharing changes which physical page a table column points at, never the
  pool contents any query attends over;
* admission skips the jitted prefill calls for shared pages (only the
  unshared tail — at least the last prompt token — runs through the step);
* reservation math reserves only unshared pages, so peak concurrency at
  equal pool memory rises with the shared fraction;
* a write into a shared page copies first (CoW on the divergent append),
  shared pages are never mutated, scrubbing happens only when a page's
  refcount reaches zero;
* digest collisions fall back to private pages (check verification),
  never to wrong content.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models.transformer import init_params
from repro.serve.engine import BlockAllocator, Request, ServingEngine, generate
from repro.serve.router import FleetRouter, sim_node


def _tiny_cfg():
    cfg = get_smoke_config("gpt3-24l")
    return dataclasses.replace(cfg, vocab_size=128, d_model=128, d_ff=256,
                               n_heads=4, n_kv_heads=4, head_dim=32)


def _params(cfg, seed=0):
    return init_params(jax.random.PRNGKey(seed), cfg)


def _ref(params, cfg, prompt, max_new):
    return generate(params, cfg, jnp.asarray([prompt], jnp.int32),
                    max_new=max_new)[0, len(prompt):].tolist()


PRE = [11, 12, 13, 14, 15, 16, 17, 18, 21, 22, 23, 24, 25, 26, 27, 28]


# ---------------------------------------------------------------------------
# Allocator unit semantics
# ---------------------------------------------------------------------------

def test_allocator_refcount_share_and_free():
    a = BlockAllocator(4)
    assert a.reserve(2)
    b0 = a.alloc_one()
    assert a.refcount[b0] == 1
    a.share(b0)
    a.share(b0)
    assert a.refcount[b0] == 3
    assert a.free([b0]) == []          # 3 -> 2: stays live, nothing scrubbed
    assert a.free([b0]) == []          # 2 -> 1
    assert b0 not in a._free
    assert a.free([b0]) == [b0]        # 1 -> 0: physically freed NOW
    assert b0 in a._free
    with pytest.raises(AssertionError, match="double free"):
        a.free([b0])  # repro-lint: disable=ALLOC001 (raises; no return)
    with pytest.raises(AssertionError, match="share of unheld"):
        a.share(b0)


def test_allocator_content_registry():
    a = BlockAllocator(4)
    assert a.reserve(3)
    b0, b1 = a.alloc_one(), a.alloc_one()
    assert a.register(123, (-1, (1, 2)), b0)
    assert a.lookup(123, (-1, (1, 2))) == b0
    # collision: same digest, different content -> verified miss
    assert a.lookup(123, (-1, (9, 9))) is None
    # first registration wins; a block advertises one digest
    assert not a.register(123, (-1, (9, 9)), b1)
    assert not a.register(456, (-1, (7, 7)), b0)
    assert a.lookup(123, (-1, (1, 2))) == b0
    # physical free drops the advertisement
    assert a.free([b0]) == [b0]
    assert a.lookup(123, (-1, (1, 2))) is None


# ---------------------------------------------------------------------------
# Engine: admission fast path + bitwise parity
# ---------------------------------------------------------------------------

def test_shared_prefix_skips_prefill_calls_bitwise():
    """Second admission with the same 2-page prefix runs only its tail
    chunks; both outputs stay bitwise-equal to the non-shared engine and
    to generate()."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    pa, pb = PRE + [100], PRE + [101]
    kw = dict(slots=2, cache_len=64, chunk=4, paged=True, page_size=8)
    outs = {}
    for share in (False, True):
        eng = ServingEngine(params, cfg, share_prefix=share, **kw)
        eng.submit(Request(0, pa, max_new=5))
        eng.tick()                     # admit + register A's pages
        eng.submit(Request(1, pb, max_new=5))
        eng.run()
        outs[share] = {r.req_id: r.generated for r in eng.finished}
        if share:
            # A: ceil(17/4)=5 calls; B: 16 of 17 tokens resident -> 1 call
            assert eng.stats["prefill_calls"] == 6
            assert eng.stats["shared_pages"] == 2
            assert eng.stats["shared_tokens"] == 16
        else:
            assert eng.stats["prefill_calls"] == 10
            assert eng.stats["shared_pages"] == 0
    assert outs[True] == outs[False]
    assert outs[True][0] == _ref(params, cfg, pa, 5)
    assert outs[True][1] == _ref(params, cfg, pb, 5)


def test_cow_on_divergent_append_bitwise():
    """B's prompt extends A's exactly (A's trailing partial page is a
    strict prefix of B's): B attaches the partial page shared, then its
    first divergent append copy-on-writes — A's page is never mutated,
    both decodes stay bitwise-correct."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    pa, pb = PRE + [50], PRE + [50, 60, 61]
    eng = ServingEngine(params, cfg, slots=2, cache_len=64, chunk=4,
                        paged=True, page_size=8)
    # both before the first tick: A registers at admission, B attaches in
    # the same _admit pass (once A starts decoding, its own append
    # deregisters the still-growing partial page — by design)
    eng.submit(Request(0, pa, max_new=8))
    eng.submit(Request(1, pb, max_new=8))
    done = {r.req_id: r.generated for r in eng.run()}
    assert eng.stats["cow_copies"] >= 1
    assert done[0] == _ref(params, cfg, pa, 8)
    assert done[1] == _ref(params, cfg, pb, 8)


def test_cow_on_exact_duplicate_prompt_bitwise():
    """Identical prompts: everything but the LAST token is attached
    shared (its logits must still be computed), and that final write
    copy-on-writes the attached partial page."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    p = PRE + [50, 51]
    eng = ServingEngine(params, cfg, slots=2, cache_len=64, chunk=4,
                        paged=True, page_size=8)
    eng.submit(Request(0, p, max_new=6))
    eng.submit(Request(1, p, max_new=6))
    done = {r.req_id: r.generated for r in eng.run()}
    # A: ceil(18/4) = 5 calls; B: 17 of 18 tokens resident -> one
    # single-token tail chunk
    assert eng.stats["prefill_calls"] == 6
    assert eng.stats["cow_copies"] == 1
    ref = _ref(params, cfg, p, 6)
    assert done[0] == ref and done[1] == ref


def test_scrub_only_at_refcount_zero():
    """The first sharer finishing must NOT scrub pages the second still
    reads (refcount > 0); the pages recycle only after the last holder
    releases them — and then the pool is fully clean."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    pa, pb = PRE + [100], PRE + [101]
    eng = ServingEngine(params, cfg, slots=2, cache_len=64, chunk=4,
                        paged=True, page_size=8)
    eng.submit(Request(0, pa, max_new=2))      # finishes first
    eng.tick()
    eng.submit(Request(1, pb, max_new=12))     # still decoding after A exits
    done = {r.req_id: r.generated for r in eng.run()}
    assert done[0] == _ref(params, cfg, pa, 2)
    assert done[1] == _ref(params, cfg, pb, 12)
    # everything released: free list whole, no refcounts, registry empty
    assert eng._alloc.n_free == eng.num_blocks
    assert eng._alloc.reserved == 0
    assert not eng._alloc.refcount and not eng._alloc._entries


def test_hash_collision_falls_back_to_private_pages():
    """All digests colliding (degenerate hash) must never attach wrong
    content: check verification turns mismatches into private pages;
    byte-identical prefixes may still share."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    pa = PRE + [100]
    pc = list(reversed(PRE)) + [102]           # different 2-page prefix
    eng = ServingEngine(params, cfg, slots=2, cache_len=64, chunk=4,
                        paged=True, page_size=8)
    eng._digest = lambda payload: 7            # force universal collisions
    eng.submit(Request(0, pa, max_new=12))     # outlives the others
    eng.submit(Request(1, pc, max_new=2))
    eng.submit(Request(2, pa + [1], max_new=4))    # byte-equal prefix to A
    done = {r.req_id: r.generated for r in eng.run()}
    assert done[0] == _ref(params, cfg, pa, 12)
    assert done[1] == _ref(params, cfg, pc, 2)
    assert done[2] == _ref(params, cfg, pa + [1], 4)
    # the colliding (different-content) prefix never shared; the
    # byte-equal page 0 still did (its check verifies; page 1's chain
    # digest is shadowed by the page-0 registration, so it stays private)
    assert eng.stats["shared_pages"] == 1


def test_sharing_raises_concurrency_at_equal_pool_memory():
    """8 requests over the same 2-page prefix, pool of 12 pages: without
    sharing each needs 4 pages (3 concurrent); with sharing all but the
    first need 2 — strictly more requests in flight, same memory, with
    backpressure accounting staying exact while shared pages are
    outstanding."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    prompts = [PRE + [100 + i] for i in range(8)]
    peaks = {}
    for share in (False, True):
        eng = ServingEngine(params, cfg, slots=8, cache_len=64, chunk=4,
                            paged=True, page_size=8, num_blocks=12,
                            share_prefix=share)
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, max_new=8))
        peak = 0
        while eng.tick() or eng.queue:
            peak = max(peak, eng.n_active)
        peaks[share] = peak
        assert eng.stats["backpressure"] > 0   # the pool did bind
        assert eng._alloc.n_free == 12 and eng._alloc.reserved == 0
        refs = [_ref(params, cfg, p, 8) for p in prompts]
        done = {r.req_id: r.generated for r in eng.finished}
        assert all(done[i] == refs[i] for i in range(8))
    assert peaks[True] > peaks[False], peaks


def test_sharing_gated_off_for_stateful_mixers():
    """Models whose skipped-prefill state would go stale (SWA rings,
    recurrent carries, MoE capacity) never share."""
    for arch in ("gemma3-12b", "rwkv6-7b"):
        cfg = get_smoke_config(arch)
        eng = ServingEngine(_params(cfg), cfg, slots=1, cache_len=64,
                            chunk=4, paged=True, page_size=8)
        assert not eng._can_share, arch
    cfg = _tiny_cfg()
    eng = ServingEngine(_params(cfg), cfg, slots=1, cache_len=64, chunk=4,
                        paged=True, page_size=8)
    assert eng._can_share


# ---------------------------------------------------------------------------
# Fleet: prefix-affinity near-tie break + failover requeue
# ---------------------------------------------------------------------------

def _fleet(params, cfg, n=2, **ekw):
    kw = dict(slots=2, cache_len=64, chunk=4, paged=True, page_size=8)
    kw.update(ekw)
    reps = [(ServingEngine(params, cfg, **kw), sim_node("rtx4090"))
            for _ in range(n)]
    return FleetRouter(reps)


def test_near_tie_breaks_toward_prefix_affinity():
    """Replica 0 holds the request's prefix pages mid-decode; replica 1
    is idle with a marginally lower ECT.  Within the near-tie band the
    router must prefer the prefix holder."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    router = _fleet(params, cfg)
    router.submit(Request(0, PRE + [100], max_new=18))
    router.tick()                       # placed on replica 0 (id tie)
    assert router.placements[0] == [0]
    router.submit(Request(1, PRE + [101], max_new=40))
    # replica 0: 17 backlog + 57 + 1 shared-tail call = 78 token-equiv;
    # replica 1: 57 + 5 full-prefill calls = 77 — replica 0 is WORSE on
    # pure ECT but within the 2% near-tie band, and holds 2 prefix pages
    router.tick()
    assert router.placements[1] == [0]
    done = {r.req_id: r.generated for r in router.run()}
    assert done[1] == _ref(params, cfg, PRE + [101], 40)


def test_exact_tie_is_deterministic_lowest_replica_id():
    """Identical idle replicas: repeated fresh dispatches must place on
    replica 0 every time (the PR 4 near-tie placement flake regression)."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    for _ in range(5):
        router = _fleet(params, cfg)
        router.submit(Request(0, [1, 2, 3], max_new=2))
        router._dispatch()
        assert router.placements[0] == [0]


def test_failover_requeue_preserves_prefix_hashes_bitwise():
    """Kill the replica holding two same-prefix requests mid-decode: the
    drained requests carry their prefix digests, re-dispatch together
    (affinity), re-share on the survivor, and finish bitwise-identical
    to generate()."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    router = _fleet(params, cfg)
    pa, pb = PRE + [100], PRE + [101]
    router.submit(Request(0, pa, max_new=18))
    router.tick()                        # req 0 decoding on replica 0
    router.submit(Request(1, pb, max_new=40))
    for _ in range(3):
        router.tick()                    # affinity co-locates req 1
    victims = [rid for rid, pl in router.placements.items() if pl == [0]]
    assert sorted(victims) == [0, 1]     # both mid-decode on replica 0
    router.fail_replica(0)
    requeued = [r for r in router.queue if r.prefix_digests is not None]
    assert len(requeued) == len(victims)
    done = {r.req_id: r.generated for r in router.run()}
    assert done[0] == _ref(params, cfg, pa, 18)
    assert done[1] == _ref(params, cfg, pb, 40)
    # the survivor re-shared the common prefix after the requeue
    survivor = next(r for r in router.replicas if r.alive)
    assert survivor.engine.stats["shared_pages"] > 0
