"""Degraded-mode fault tolerance: the serve.faults injection plane and
the FleetRouter machinery that survives it.

Covers the fault taxonomy one kind at a time — straggle (ECT inflation,
soft-drain past the threshold, mild stragglers left alone), partition
(state retained across heal, escalation to crash past the timeout),
pool_pressure (admission backpressure only, never a decode crash) — plus
the head-of-line preemption path, retry budgets with structured
outcomes, the FleetResult trace surface, and the dead-standby
regressions.  Every survivor is checked bitwise against a no-fault
reference run: faults may move work around, but they must never change
what a completed request generated.
"""
import dataclasses

import jax
import pytest

from repro.configs import get_smoke_config
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServingEngine
from repro.serve.faults import FAULT_KINDS, Fault, FaultPlan
from repro.serve.router import FleetRouter, sim_node


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_smoke_config("gpt3-24l"), vocab_size=128,
                              d_model=128, d_ff=256, n_heads=4, n_kv_heads=4,
                              head_dim=32)
    return init_params(jax.random.PRNGKey(0), cfg), cfg


def _engine(params, cfg, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("cache_len", 64)
    kw.setdefault("chunk", 8)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 16)
    return ServingEngine(params, cfg, **kw)


def _requests(n, cfg, max_new=6, **kw):
    return [Request(i, [(3 + 5 * i + j) % cfg.vocab_size
                        for j in range(4 + i % 3)], max_new=max_new, **kw)
            for i in range(n)]


def _reference(params, cfg, n, devices=("rtx4090", "rtx3080"), max_new=6):
    """No-fault fleet run over the canonical workload: req_id -> tokens."""
    router = FleetRouter([(_engine(params, cfg), d) for d in devices])
    for r in _requests(n, cfg, max_new=max_new):
        router.submit(r)
    res = router.run()
    assert sorted(r.req_id for r in res.completed) == list(range(n))
    return {r.req_id: list(r.generated) for r in res.completed}


# ---------------------------------------------------------------------------
# The plan itself
# ---------------------------------------------------------------------------

def test_fault_plan_validation():
    with pytest.raises(ValueError, match="unknown kind"):
        Fault(0, 0, "meteor")
    with pytest.raises(ValueError, match="tick"):
        Fault(-1, 0, "crash")
    with pytest.raises(ValueError, match="factor"):
        Fault(0, 0, "straggle", factor=0.5)
    with pytest.raises(ValueError, match="duration"):
        Fault(0, 0, "partition", duration=0)
    with pytest.raises(ValueError, match="page"):
        Fault(0, 0, "pool_pressure", pages=0)
    with pytest.raises(TypeError):
        FaultPlan().add("crash")


def test_fault_plan_seeded_deterministic():
    kw = dict(ticks=50, replica_ids=[0, 1, 2], rate=0.2)
    a = FaultPlan.seeded(7, **kw)
    b = FaultPlan.seeded(7, **kw)
    c = FaultPlan.seeded(8, **kw)
    assert list(a) == list(b) and len(a) > 0
    assert list(a) != list(c)
    assert all(f.kind in FAULT_KINDS for f in a)
    # at() returns exactly the faults of that tick, grouped
    assert sorted(f.tick for f in a) == [f.tick for f in a]
    assert sum(len(a.at(t)) for t in range(50)) == len(a)


# ---------------------------------------------------------------------------
# Straggle: ECT inflation, soft-drain, mild degradation tolerated
# ---------------------------------------------------------------------------

def test_straggler_soft_drained_and_work_moves(tiny):
    params, cfg = tiny
    ref = _reference(params, cfg, 4)
    plan = FaultPlan([Fault(2, 0, "straggle", factor=8.0, duration=10)])
    router = FleetRouter([(_engine(params, cfg), "rtx4090"),
                          (_engine(params, cfg), "rtx3080")],
                         fault_plan=plan)
    for r in _requests(4, cfg):
        router.submit(r)
    res = router.run(max_ticks=300)
    assert sorted(r.req_id for r in res.completed) == [0, 1, 2, 3]
    assert {i: list(r.generated) for i, r in
            ((r.req_id, r) for r in res.completed)} == ref
    assert router.stats["straggles"] >= 1
    assert router.stats["soft_drains"] >= 1
    # the straggler's ECT multiplier actually rose
    assert router.replicas[0].lat_ewma > 1.0 or \
        router.stats["soft_drains"] >= 1
    # soft-drain victims were requeued-from-prompt (one retry each) and
    # re-placed — nothing was dropped, and the survivors are bitwise ok
    victims = [r for r in res.completed if r.retries > 0]
    assert victims, "an 8x straggler with in-flight work must soft-drain"
    for v in victims:
        assert len(router.placements[v.req_id]) > 1


def test_mild_straggler_not_drained(tiny):
    """A replica straggling below drain_factor keeps its work: the EWMA
    prices it out of NEW placement but in-flight decode rides it out."""
    params, cfg = tiny
    ref = _reference(params, cfg, 4)
    plan = FaultPlan([Fault(2, 0, "straggle", factor=2.0, duration=4)])
    router = FleetRouter([(_engine(params, cfg), "rtx4090"),
                          (_engine(params, cfg), "rtx3080")],
                         fault_plan=plan)
    for r in _requests(4, cfg):
        router.submit(r)
    res = router.run(max_ticks=300)
    assert router.stats["soft_drains"] == 0
    assert all(r.retries == 0 for r in res.completed)
    assert {r.req_id: list(r.generated) for r in res.completed} == ref


# ---------------------------------------------------------------------------
# Partition: state retained on heal, escalation past the timeout
# ---------------------------------------------------------------------------

def test_partition_heals_without_reprefill(tiny):
    params, cfg = tiny
    ref = _reference(params, cfg, 4)
    plan = FaultPlan([Fault(2, 0, "partition", duration=5)])
    router = FleetRouter([(_engine(params, cfg), "rtx4090"),
                          (_engine(params, cfg), "rtx3080")],
                         fault_plan=plan)
    for r in _requests(4, cfg):
        router.submit(r)
    for _ in range(3):
        router.tick()
    frozen = {r.req_id for r in router.replicas[0].engine.active
              if r is not None}
    assert frozen, "placement must have put work on replica 0 by tick 3"
    res = router.run(max_ticks=300)
    assert router.stats["partitions"] >= 1
    assert router.stats["partition_heals"] >= 1
    assert router.stats["requeued"] == 0
    assert sorted(r.req_id for r in res.completed) == [0, 1, 2, 3]
    assert {r.req_id: list(r.generated) for r in res.completed} == ref
    # the in-flight work survived the partition in place: no second
    # placement, no retry, no re-admission (re-prefill) on the engine
    for r in res.completed:
        if r.req_id in frozen:
            assert router.placements[r.req_id] == [0]
            assert r.retries == 0
    # every admission on replica 0 is accounted by exactly one placement
    # there: nothing was re-admitted (= re-prefilled) after the heal
    assert router.replicas[0].engine.stats["admitted"] == \
        sum(pl.count(0) for pl in router.placements.values())


def test_partition_escalates_to_crash_past_timeout(tiny):
    params, cfg = tiny
    ref = _reference(params, cfg, 4)
    plan = FaultPlan([Fault(2, 0, "partition", duration=100)])
    router = FleetRouter([(_engine(params, cfg), "rtx4090"),
                          (_engine(params, cfg), "rtx3080")],
                         fault_plan=plan, partition_timeout=4)
    for r in _requests(4, cfg):
        router.submit(r)
    res = router.run(max_ticks=300)
    assert router.stats["partition_escalations"] == 1
    assert router.stats["failures"] == 1
    assert not router.replicas[0].alive
    assert sorted(r.req_id for r in res.completed) == [0, 1, 2, 3]
    assert {r.req_id: list(r.generated) for r in res.completed} == ref
    # the escalation went through the crash path: victims re-prefilled
    # on the survivor and paid one retry
    victims = [r for r in res.completed if r.retries == 1]
    assert victims and all(router.placements[v.req_id][-1] == 1
                           for v in victims)


# ---------------------------------------------------------------------------
# Pool pressure: admission backpressure only, never a decode crash
# ---------------------------------------------------------------------------

def test_pool_pressure_backpressures_admission_only(tiny):
    params, cfg = tiny
    eng = _engine(params, cfg, num_blocks=4)
    assert eng.free_pages == 4
    eng.set_pool_pressure(3)
    assert eng.free_pages == 1
    eng.submit(Request(0, [1, 2, 3], max_new=20))    # needs 2 pages
    eng.tick()
    assert eng.stats["backpressure"] == 1 and eng.n_active == 0
    eng.set_pool_pressure(0)
    eng.tick()
    assert eng.n_active == 1                          # pressure lifted
    # dense engines are page-unconstrained: pressure is a no-op
    dense = ServingEngine(params, cfg, slots=2, cache_len=64, chunk=8)
    dense.set_pool_pressure(10)
    assert dense.free_pages > 1 << 20


def test_pool_pressure_fault_expires(tiny):
    params, cfg = tiny
    ref = _reference(params, cfg, 4)
    plan = FaultPlan([Fault(1, 0, "pool_pressure", pages=64, duration=4),
                      Fault(1, 1, "pool_pressure", pages=64, duration=4)])
    router = FleetRouter([(_engine(params, cfg), "rtx4090"),
                          (_engine(params, cfg), "rtx3080")],
                         fault_plan=plan)
    for r in _requests(4, cfg):
        router.submit(r)
    res = router.run(max_ticks=300)
    assert router.stats["pool_pressure"] == 2
    assert router.replicas[0].engine._alloc.withheld == 0   # restored
    assert sorted(r.req_id for r in res.completed) == [0, 1, 2, 3]
    assert {r.req_id: list(r.generated) for r in res.completed} == ref


# ---------------------------------------------------------------------------
# Head-of-line preemption
# ---------------------------------------------------------------------------

def test_hol_patience_preempts_newest(tiny):
    """A big head request held past hol_patience preempts the NEWEST
    admitted request on its best replica; the victim is requeued from
    its prompt (no retry cost) and both eventually complete bitwise."""
    params, cfg = tiny
    # 5-page pool: two small long-runners reserve 2 pages each, the big
    # head needs 3 -> held until preemption frees the newest
    eng = _engine(params, cfg, num_blocks=5)
    router = FleetRouter([(eng, "rtx4090")], hol_patience=2)
    small = [Request(i, [3 + i, 4 + i, 5 + i], max_new=25)   # 2 pages
             for i in range(2)]
    big = Request(2, [9, 10, 11, 12, 13, 14, 15, 16], max_new=38)  # 3 pages
    for r in small + [big]:
        router.submit(r)
    res = router.run(max_ticks=400)
    assert router.stats["preempted"] >= 1
    assert sorted(r.req_id for r in res.completed) == [0, 1, 2]
    assert all(r.outcome == "ok" for r in res.completed)
    # the victim was the newest admitted (req 1), requeued not dropped,
    # and preemption cost it no retry budget
    assert len(router.placements[1]) == 2
    assert next(r for r in res.completed if r.req_id == 1).retries == 0
    # single replica, greedy decode: outputs match a fleet that was
    # never fragmented (reference run with a big enough pool)
    ref_eng = _engine(params, cfg, num_blocks=8)
    ref_router = FleetRouter([(ref_eng, "rtx4090")])
    for r in [Request(i, list(q.prompt), max_new=q.max_new)
              for i, q in enumerate(small + [big])]:
        ref_router.submit(r)
    ref = {r.req_id: list(r.generated) for r in ref_router.run()}
    assert {r.req_id: list(r.generated) for r in res.completed} == ref


# ---------------------------------------------------------------------------
# Retry budgets + structured outcomes + traces
# ---------------------------------------------------------------------------

def test_retry_budget_exhausts_to_failed_retries(tiny):
    """A poisoned request that keeps riding dying replicas stops
    consuming the fleet after max_retries; everyone else completes."""
    params, cfg = tiny
    router = FleetRouter([(_engine(params, cfg), "rtx4090"),
                          (_engine(params, cfg), "rtx3080")],
                         standby=[(_engine(params, cfg), "rtx3080")])
    reqs = _requests(3, cfg)
    poison = Request(3, [11, 12, 13, 14], max_new=8, max_retries=1)
    for r in reqs + [poison]:
        router.submit(r)
    kills = 0
    for _ in range(400):
        router.tick()
        if kills < 2 and poison.outcome is None:
            placed = router.placements.get(3, [])
            if placed:
                rep = next(r for r in router.replicas
                           if r.replica_id == placed[-1])
                if rep.alive and any(a is poison for a in rep.engine.active):
                    router.fail_replica(rep.replica_id)
                    kills += 1
        if not router.outstanding():
            break
    res = router.run(max_ticks=400)
    assert kills == 2
    assert poison.outcome == "failed_retries" and poison.retries == 2
    assert [r.req_id for r in res.failed] == [3]
    assert sorted(r.req_id for r in res.completed) == [0, 1, 2]
    assert res.outcomes() == {"ok": 3, "failed_retries": 1}
    tr = res.traces[3]
    assert tr["outcome"] == "failed_retries" and tr["retries"] == 2
    assert len(tr["placements"]) == 2


def test_deadline_exceeded_outcome(tiny):
    params, cfg = tiny
    router = FleetRouter([(_engine(params, cfg), "rtx4090")])
    for r in _requests(3, cfg, max_new=8):
        router.submit(r)
    res = router.run(max_ticks=2)
    assert res.completed == []
    assert sorted(r.req_id for r in res.failed) == [0, 1, 2]
    assert all(r.outcome == "deadline_exceeded" for r in res.failed)
    # terminal: a second run does not resurrect them
    res2 = router.run(max_ticks=50)
    assert res2.completed == [] and len(res2.failed) == 3


def test_result_traces_latency(tiny):
    params, cfg = tiny
    router = FleetRouter([(_engine(params, cfg), "rtx4090")])
    for r in _requests(2, cfg, max_new=4):
        router.submit(r)
    res = router.run()
    for rid in (0, 1):
        tr = res.traces[rid]
        assert tr["outcome"] == "ok" and tr["generated"] == 4
        assert tr["latency_ticks"] == tr["finished_tick"] - tr["submitted_tick"]
        assert tr["latency_ticks"] > 0 and tr["placements"] == [0]


# ---------------------------------------------------------------------------
# Dead standbys are never drafted (fleet level; broker level lives in
# test_broker_failover.py)
# ---------------------------------------------------------------------------

def test_dead_standby_never_drafted(tiny):
    params, cfg = tiny
    router = FleetRouter(
        [(_engine(params, cfg), sim_node("rtx4090", reliability=1.0))],
        standby=[(_engine(params, cfg), sim_node("rtx3080",
                                                 reliability=0.0))])
    for r in _requests(2, cfg):
        router.submit(r)
    router.tick()
    dead = router.heartbeat_round()        # the standby dies in round 1
    assert dead and router.stats["standby_deaths"] == 1
    assert not router._standby and not router.broker.backup
    router.fail_replica(0)
    res = router.run()
    # with the standby dead there is nothing to draft: requests fail
    # terminally instead of a corpse being activated
    assert router.stats["replacements"] == 0
    assert all(r.outcome == "failed_unservable" for r in res.failed)
    assert len(res.failed) == 2
