"""FleetRouter tests: broker-routed multi-engine serving.

End-to-end failover (the acceptance criterion): with >= 3 replicas and a
seeded heartbeat failure mid-decode, every submitted request completes,
the replacement is drafted from the backup pool by speed match, and
unaffected replicas' outputs are bitwise-identical to a no-failure run.
Plus: Eq. 2 placement skew toward fast simulated devices,
heterogeneous-config routing (vocab / context / pool gating), the engine
occupancy/drain hooks the router runs on, and fleet-death reporting.
"""
import dataclasses

import jax
import pytest

from repro.configs import get_smoke_config
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServingEngine
from repro.serve.router import FleetRouter, sim_node


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_smoke_config("gpt3-24l"), vocab_size=128,
                              d_model=128, d_ff=256, n_heads=4, n_kv_heads=4,
                              head_dim=32)
    return init_params(jax.random.PRNGKey(0), cfg), cfg


def _engine(params, cfg, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("cache_len", 64)
    kw.setdefault("chunk", 8)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 16)
    return ServingEngine(params, cfg, **kw)


def _uniform_requests(n, cfg, max_new=6):
    return [Request(i, [(3 + 5 * i + j) % cfg.vocab_size
                        for j in range(4 + i % 3)], max_new=max_new)
            for i in range(n)]


# ---------------------------------------------------------------------------
# Engine hooks the router is built on
# ---------------------------------------------------------------------------

def test_engine_occupancy_and_pending_tokens(tiny):
    params, cfg = tiny
    eng = _engine(params, cfg)
    assert eng.pending_tokens == 0
    assert eng.occupancy["free_slots"] == 2
    eng.submit(Request(0, [1, 2, 3], max_new=5))
    assert eng.pending_tokens == 8              # queued: prompt + max_new
    eng.tick()                                  # admit + first decode
    # admitted: prefill paid, one token generated -> 4 decode tokens left
    assert eng.pending_tokens == 4
    occ = eng.occupancy
    assert occ["active"] == 1 and occ["queued"] == 0
    assert occ["free_pages"] == eng.num_blocks - 1   # 8-token request


def test_engine_free_pages_counts_queued_demand(tiny):
    params, cfg = tiny
    eng = _engine(params, cfg)                   # pool = 8 pages of 16
    eng.submit(Request(0, list(range(1, 9)), max_new=40))   # 3 pages
    assert eng.free_pages == eng.num_blocks - 3  # queued demand counted
    eng.submit(Request(1, list(range(1, 9)), max_new=40))
    assert eng.free_pages == eng.num_blocks - 6


def test_engine_can_serve_bounds(tiny):
    params, cfg = tiny
    eng = _engine(params, cfg)
    assert eng.can_serve([1, 2, 3], 4)
    assert not eng.can_serve([], 4)                        # empty prompt
    assert not eng.can_serve([cfg.vocab_size], 4)          # vocab bound
    assert not eng.can_serve([1] * 60, 10)                 # wraps cache_len
    small = _engine(params, cfg, num_blocks=2)
    assert not small.can_serve([1] * 30, 30)               # > pool size


def test_engine_drain_order_is_admission_order_after_slot_recycle(tiny):
    """Slot index lies about age once slots recycle: A (slot 0) finishes,
    younger C lands in slot 0 while B (slot 1) still runs — drain must
    return [B, C], not [C, B]."""
    params, cfg = tiny
    eng = _engine(params, cfg)
    eng.submit(Request(0, [1, 2], max_new=1))      # A: finishes first
    eng.submit(Request(1, [3, 4], max_new=8))      # B: slot 1, long
    eng.tick()                                     # A done, slot 0 free
    assert eng.finished and eng.finished[0].req_id == 0
    eng.submit(Request(2, [5, 6], max_new=8))      # C: recycles slot 0
    eng.tick()
    assert [r.req_id for r in eng.drain_requests()] == [1, 2]


def test_engine_drain_resets_requests_and_empties_engine(tiny):
    params, cfg = tiny
    eng = _engine(params, cfg)
    for r in _uniform_requests(3, cfg):
        eng.submit(r)
    for _ in range(2):
        eng.tick()                 # 2 admitted + decoding, 1 still queued
    assert eng.n_active == 2 and len(eng.queue) == 1
    drained = eng.drain_requests()
    assert [r.req_id for r in drained] == [0, 1, 2]   # slots first, FIFO
    assert all(r.generated == [] and r.pending == -1 and not r.done
               for r in drained)
    assert eng.n_active == 0 and not eng.queue
    assert eng.free_pages == eng.num_blocks           # every page back
    # the engine still serves correctly after a drain (fresh admission)
    eng.submit(drained[0])
    eng.run()
    assert len(eng.finished[-1].generated) == drained[0].max_new


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------

def test_single_replica_matches_plain_engine(tiny):
    params, cfg = tiny
    reqs = _uniform_requests(3, cfg)
    plain = _engine(params, cfg)
    for r in reqs:
        plain.submit(Request(r.req_id, list(r.prompt), max_new=r.max_new))
    ref = {r.req_id: r.generated for r in plain.run()}
    router = FleetRouter([(_engine(params, cfg), "rtx4090")])
    for r in reqs:
        router.submit(r)
    out = {r.req_id: r.generated for r in router.run()}
    assert out == ref


def test_placement_skews_toward_faster_device(tiny):
    params, cfg = tiny
    router = FleetRouter([(_engine(params, cfg), "rtx4090"),
                          (_engine(params, cfg), "rtx3080")])
    for i in range(8):
        router.submit(Request(i, [1, 2, 3, 4], max_new=4))   # uniform
    done = router.run()
    assert len(done) == 8
    fast, slow = router.replicas
    assert len(fast.served) > len(slow.served), (fast.served, slow.served)
    # proportional-to-speed split: 8 * 82.58/(82.58+59.5) ~ 4.65 -> 5 v 3
    assert len(fast.served) == 5 and len(slow.served) == 3


def test_heterogeneous_config_routing(tiny):
    """Replicas with DIFFERENT models: requests route only to replicas
    whose vocab / context length / pool can actually run them."""
    params, cfg = tiny
    small_cfg = dataclasses.replace(cfg, vocab_size=64)
    small_params = init_params(jax.random.PRNGKey(1), small_cfg)
    router = FleetRouter(
        [(_engine(params, cfg), "rtx3080"),                  # vocab 128
         (_engine(small_params, small_cfg, cache_len=32), "a100")])
    big_vocab = Request(0, [100, 101], max_new=3)       # only replica 0
    long_ctx = Request(1, [2] * 30, max_new=10)         # 40 > 32: only 0
    anywhere = Request(2, [1, 2, 3], max_new=3)
    for r in (big_vocab, long_ctx, anywhere):
        router.submit(r)
    done = router.run()
    assert sorted(r.req_id for r in done) == [0, 1, 2]
    assert router.placements[0] == [0]
    assert router.placements[1] == [0]
    # the third is legal on both; the a100 replica is idle AND faster
    assert router.placements[2] == [1]
    with pytest.raises(ValueError):
        router.submit(Request(9, [500], max_new=2))     # nobody's vocab


def test_head_unservable_on_live_fleet_drafts_capable_standby(tiny):
    """A request only a STANDBY's model can run must not hold the queue
    forever waiting for a failure: the router drafts the capable standby
    at dispatch time and every request (including those queued behind
    the head) completes."""
    params, cfg = tiny
    small_cfg = dataclasses.replace(cfg, vocab_size=64)
    small_params = init_params(jax.random.PRNGKey(1), small_cfg)
    router = FleetRouter(
        [(_engine(small_params, small_cfg), "rtx3080")],     # vocab 64
        [(_engine(params, cfg), "rtx4090")])                 # vocab 128
    router.submit(Request(0, [100, 101], max_new=3))   # needs the standby
    router.submit(Request(1, [1, 2, 3], max_new=3))    # behind the head
    done = router.run()
    assert sorted(r.req_id for r in done) == [0, 1]
    assert router.stats["replacements"] == 1
    assert router.placements[0] == [router.replicas[-1].replica_id]
    assert not router._standby


def test_router_rejects_unservable_request(tiny):
    params, cfg = tiny
    router = FleetRouter([(_engine(params, cfg), "rtx4090")])
    with pytest.raises(ValueError):
        router.submit(Request(0, [1] * 60, max_new=30))   # wraps cache


# ---------------------------------------------------------------------------
# Failover (acceptance criterion)
# ---------------------------------------------------------------------------

def _fleet(params, cfg, *, kill_replica_1: bool):
    """3 actives (rtx4090 + 2x rtx3080) + 1 rtx3080 standby; replica 1
    carries reliability 0 in the failure run, so the FIRST heartbeat
    round (tick 2, mid-decode) kills exactly it, deterministically."""
    nodes = [sim_node("rtx4090", reliability=1.0),
             sim_node("rtx3080",
                      reliability=0.0 if kill_replica_1 else 1.0),
             sim_node("rtx3080", reliability=1.0)]
    return FleetRouter([(_engine(params, cfg), n) for n in nodes],
                       [(_engine(params, cfg),
                         sim_node("rtx3080", reliability=1.0))], seed=0)


def test_fleet_failover_end_to_end(tiny):
    params, cfg = tiny
    reqs = _uniform_requests(8, cfg)

    calm = _fleet(params, cfg, kill_replica_1=False)
    for r in reqs:
        calm.submit(Request(r.req_id, list(r.prompt), max_new=r.max_new))
    ref = {r.req_id: r.generated for r in calm.run(heartbeat_every=2)}
    assert calm.stats["failures"] == 0

    stormy = _fleet(params, cfg, kill_replica_1=True)
    for r in reqs:
        stormy.submit(r)
    out = {r.req_id: r.generated for r in stormy.run(heartbeat_every=2)}

    # the failure really struck mid-decode: replica 1 had live requests
    assert stormy.stats["failures"] == 1
    assert stormy.stats["requeued"] >= 1
    # every submitted request still completes, with its full max_new
    assert sorted(out) == [r.req_id for r in reqs]
    assert all(len(out[r.req_id]) == r.max_new for r in reqs)
    # the replacement was drafted from the backup pool by speed match:
    # an rtx3080 died, the rtx3080 standby (not nothing, and it would
    # beat any faster standby) came in
    assert stormy.stats["replacements"] == 1
    drafted = stormy.replicas[-1]
    assert drafted.alive and drafted.node.device.name == "rtx3080"
    dead = next(r for r in stormy.replicas if not r.alive)
    assert dead.replica_id == 1
    # unaffected replicas' outputs are bitwise-identical to the
    # no-failure run (slot isolation: extra/requeued traffic cannot
    # perturb co-resident greedy decode)
    unaffected = [rid for rid, reps in stormy.placements.items()
                  if 1 not in reps]
    assert unaffected, "some requests must have avoided the dead replica"
    for rid in unaffected:
        assert out[rid] == ref[rid], rid
    # and with shared params + greedy decode, re-prefill is exact, so
    # even the requeued requests reproduce the no-failure tokens
    assert out == ref


def test_failover_speed_match_prefers_matching_standby(tiny):
    """Two standbys of different speeds: killing the slow replica must
    draft the slow standby; the fast standby stays in reserve."""
    params, cfg = tiny
    router = FleetRouter(
        [(_engine(params, cfg), sim_node("rtx3080", reliability=1.0)),
         (_engine(params, cfg), sim_node("a100", reliability=1.0))],
        [(_engine(params, cfg), sim_node("a100", reliability=1.0)),
         (_engine(params, cfg), sim_node("rtx3080", reliability=1.0))])
    for r in _uniform_requests(4, cfg):
        router.submit(r)
    router.tick()
    router.fail_replica(0)                      # the rtx3080 dies
    done = router.run()
    assert len(done) == 4
    drafted = router.replicas[-1]
    assert drafted.node.device.name == "rtx3080"
    assert len(router._standby) == 1            # the a100 stayed back


def test_simultaneous_deaths_requeue_in_submission_order(tiny):
    """Two replicas die in ONE heartbeat round: the per-replica drains
    must merge back into GLOBAL submission order, not interleave the
    second victim's (younger or older) requests ahead of the first's."""
    params, cfg = tiny
    router = FleetRouter(
        [(_engine(params, cfg), sim_node("rtx4090", reliability=1.0)),
         (_engine(params, cfg), sim_node("rtx3080", reliability=0.0)),
         (_engine(params, cfg), sim_node("rtx3080", reliability=0.0))],
        [(_engine(params, cfg), sim_node("rtx3080", reliability=1.0))],
        seed=0)
    for r in _uniform_requests(6, cfg):
        router.submit(r)
    for _ in range(2):
        router.tick()
    dead = router.heartbeat_round()
    assert len(dead) == 2
    # admission-aware ECT placement: rtx4090 holds reqs 0/3, the victims
    # hold 1/4 and 2/5 — without the post-drain sort the per-replica
    # prepends would leave [2, 5, 1, 4]
    assert router.stats["requeued"] == 4
    ids = [r.req_id for r in router.queue]
    assert len(ids) >= 2 and ids == sorted(ids), ids   # global FIFO
    done = router.run()
    assert sorted(r.req_id for r in done) == list(range(6))


def test_failover_without_standby_absorbs_on_survivors(tiny):
    params, cfg = tiny
    router = FleetRouter([(_engine(params, cfg), "rtx4090"),
                          (_engine(params, cfg), "rtx3080")])
    for r in _uniform_requests(5, cfg):
        router.submit(r)
    for _ in range(2):
        router.tick()
    router.fail_replica(1)
    done = router.run()
    assert sorted(r.req_id for r in done) == [0, 1, 2, 3, 4]
    assert router.stats["replacements"] == 0
    # everything after the failure ran on the survivor
    assert sorted(router.replicas[0].served + router.replicas[1].served) \
        == [0, 1, 2, 3, 4]


def test_fleet_death_returns_structured_failures(tiny):
    """Killing the whole fleet no longer raises away partial results:
    run() returns a FleetResult with every request terminally failed
    (outcome failed_unservable) — strict=True restores the raise."""
    params, cfg = tiny
    router = FleetRouter([(_engine(params, cfg), "rtx4090")])
    for r in _uniform_requests(3, cfg):
        router.submit(r)
    router.tick()
    router.fail_replica(0)
    res = router.run()
    assert len(res.completed) == 0
    assert sorted(r.req_id for r in res.failed) == [0, 1, 2]
    assert all(r.outcome == "failed_unservable" for r in res.failed)
    assert res.outcomes() == {"failed_unservable": 3}
    assert not res.ok


def test_fleet_death_strict_raises(tiny):
    params, cfg = tiny
    router = FleetRouter([(_engine(params, cfg), "rtx4090")])
    for r in _uniform_requests(3, cfg):
        router.submit(r)
    router.tick()
    router.fail_replica(0)
    with pytest.raises(RuntimeError, match="strict"):
        router.run(strict=True)


def test_fail_replica_unknown_id_and_double_kill(tiny):
    """fail_replica on an id the fleet never activated raises a
    descriptive ValueError (not a bare StopIteration); a second kill of
    the same replica is a no-op, like _on_death already is."""
    params, cfg = tiny
    router = FleetRouter([(_engine(params, cfg), "rtx4090"),
                          (_engine(params, cfg), "rtx3080")],
                         standby=[(_engine(params, cfg), "rtx3080")])
    with pytest.raises(ValueError, match="unknown replica id 99"):
        router.fail_replica(99)
    # an undrafted standby is not an active replica either
    with pytest.raises(ValueError, match="standby"):
        router.fail_replica(2)
    router.fail_replica(1)
    failures = router.stats["failures"]
    router.fail_replica(1)              # no-op, no StopIteration, no raise
    assert router.stats["failures"] == failures


def test_on_death_requeues_direct_engine_submits(tiny):
    """A request admitted directly via engine.submit() (bypassing the
    router) has no submission-order entry; a failover drain must not
    KeyError on it — it joins the order book at drain time."""
    params, cfg = tiny
    router = FleetRouter([(_engine(params, cfg), "rtx4090"),
                          (_engine(params, cfg), "rtx3080")])
    for r in _uniform_requests(3, cfg):
        router.submit(r)
    router.tick()
    stowaway = Request(req_id=77, prompt=[5, 6, 7], max_new=4)
    router.replicas[0].engine.submit(stowaway)
    router.fail_replica(0)              # must not KeyError on req 77
    assert 77 in {r.req_id for r in router.queue}
    res = router.run()
    assert sorted(r.req_id for r in res.completed) == [0, 1, 2, 77]
    assert all(r.outcome == "ok" for r in res.completed)
