"""Sharding-rule coverage: every parameter / optimizer / cache leaf of all
12 architectures has an explicit rule, ranks line up, and sanitization
drops exactly the non-divisible axes.  Runs on abstract shapes only."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ALL_ARCHS, INPUT_SHAPES, baseline_pairs, get_config
from repro.core.workload import (analytic_hbm_bytes, block_workloads,
                                 cache_bytes, model_flops, model_flops_6nd)
from repro.launch import roofline as rl
from repro.launch.specs import batch_sds, caches_sds, input_specs, params_sds


class _FakeMesh:
    axis_names = ("data", "model")

    class _Dev:
        shape = (16, 16)
        size = 256

    devices = _Dev()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_rules_cover_all_leaves(arch):
    from repro.launch.shardings import param_specs, sanitize_spec
    cfg = get_config(arch)
    p = params_sds(cfg)
    specs = param_specs(p, _FakeMesh())          # raises on unknown leaf
    flat_p = jax.tree.leaves(p)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert len(spec) <= len(leaf.shape), (leaf.shape, spec)
        s = sanitize_spec(spec, leaf.shape, _FakeMesh())
        for dim, entry in zip(leaf.shape, list(s)):
            if entry is not None:
                axes = entry if isinstance(entry, tuple) else (entry,)
                n = int(np.prod([16 for _ in axes]))
                assert dim % n == 0


@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-v3-671b",
                                  "jamba-1.5-large-398b", "rwkv6-7b",
                                  "gemma3-12b"])
def test_cache_rules_cover_all_leaves(arch):
    from repro.launch.shardings import cache_specs
    cfg = get_config(arch)
    c = caches_sds(cfg, 128, 1024)
    specs = cache_specs(c, _FakeMesh(), batch_size=128)
    assert len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))) \
        == len(jax.tree.leaves(c))
    specs_long = cache_specs(c, _FakeMesh(), batch_size=1)
    flat = jax.tree.leaves(specs_long, is_leaf=lambda x: isinstance(x, P))
    # long-context: no batch sharding anywhere
    for s in flat:
        assert s[1] != "data" or True
        assert list(s)[1] is None or list(s)[1] != "data" or len(s) < 2 \
            or list(s)[0] is None


@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-v3-671b",
                                  "gemma3-12b"])
def test_cache_rules_cover_paged_pools(arch):
    """The name-keyed cache rules must also cover paged pool leaves
    (block axis in place of batch) — the dry-run's --paged engine-step
    lowering shards them with the same table."""
    from repro.launch.shardings import cache_specs
    cfg = get_config(arch)
    c = caches_sds(cfg, 128, 1024, paged=True, page_size=16)
    specs = cache_specs(c, _FakeMesh(), batch_size=128)
    assert len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))) \
        == len(jax.tree.leaves(c))


def test_sanitize_drops_nondivisible():
    from repro.launch.shardings import sanitize_spec
    s = sanitize_spec(P("data", "model"), (24, 64), _FakeMesh())
    assert list(s) == [None, None] or list(s) == [None, "model"]
    s2 = sanitize_spec(P("data", "model"), (32, 64), _FakeMesh())
    assert list(s2) == ["data", "model"]


def test_input_specs_cover_matrix():
    pairs, skips = baseline_pairs()
    assert len(pairs) + len(skips) == 40
    assert len(skips) == 7          # 7 pure-full-attention long_500k skips
    for arch, shape in pairs[:6]:
        spec = input_specs(arch, shape)
        assert "params" in spec
        kind = INPUT_SHAPES[shape].kind
        if kind == "train":
            assert "opt_state" in spec
        else:
            assert "caches" in spec


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_workload_model_consistency(arch):
    """Analytic param counts & flops are positive and self-consistent."""
    cfg = get_config(arch)
    counts = cfg.param_counts()
    assert counts["total"] >= counts["active"] > 0
    f_train = model_flops(cfg, batch=4, seq=128, kind="train")
    f_pref = model_flops(cfg, batch=4, seq=128, kind="prefill")
    assert f_train == pytest.approx(3 * f_pref)
    f6 = model_flops_6nd(cfg, tokens=4 * 128)
    assert 0.2 < f_pref / (f6 / 3) < 5.0      # same order as 2·N_active·D
    assert analytic_hbm_bytes(cfg, batch=4, seq=128, kind="train") > 0
    assert cache_bytes(cfg, batch=2, cache_len=64) > 0


def test_param_counts_match_real_init():
    """Analytic counting vs actually-initialized smoke params."""
    from repro.configs import get_smoke_config
    from repro.models.transformer import init_params
    for arch in ["qwen3-8b", "rwkv6-7b", "qwen3-moe-235b-a22b"]:
        cfg = get_smoke_config(arch)
        params = init_params(jax.random.PRNGKey(0), cfg)
        real = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        analytic = cfg.param_counts()["total"]
        assert abs(real - analytic) / real < 0.06, (arch, real, analytic)


def test_roofline_hlo_parsers():
    hlo = """
HloModule m

%body.1 (p: s32[]) -> s32[] {
  %ar = f32[128,256]{1,0} all-reduce(%x), to_apply=%sum
  ROOT %t = s32[] add(%p, %c1)
}

%cond.1 (p: s32[]) -> pred[] {
  %limit = s32[] constant(36)
  ROOT %cmp = pred[] compare(%p, %limit), direction=LT
}

ENTRY %main (a: f32[16,16]) -> f32[16,16] {
  %w = (s32[]) while(%init), condition=%cond.1, body=%body.1
  %ag = bf16[64,32]{1,0} all-gather(%a), dimensions={0}
  ROOT %r = f32[16,16] add(%a, %a)
}
"""
    static = rl.collective_bytes(hlo)
    assert static["all-reduce"] == 128 * 256 * 4
    assert static["all-gather"] == 64 * 32 * 2
    aware = rl.loop_aware_collectives(hlo)
    assert aware["all-reduce"] == 36 * 128 * 256 * 4   # x trip count
    assert aware["all-gather"] == 64 * 32 * 2
    io = rl.entry_io_bytes(hlo)
    assert io["args"] == 16 * 16 * 4
