"""Paged slot cache tests: paged-vs-dense-vs-``generate()`` greedy parity
across cache kinds (full-attention KV, SWA ring pages, MLA latent pool,
recurrent state), block-allocator accounting (reservation admission,
free-list recycle, double-free detection), pool-exhaustion backpressure,
block-recycle scrubbing, per-slot in-jit sampling, and the
more-concurrency-at-equal-memory property the paging exists for."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.transformer import init_cache, init_params
from repro.serve.engine import (BlockAllocator, Request, ServingEngine,
                                generate, make_clear_blocks)


def _tiny_cfg():
    cfg = get_smoke_config("gpt3-24l")
    return dataclasses.replace(cfg, vocab_size=128, d_model=128, d_ff=256,
                               n_heads=4, n_kv_heads=4, head_dim=32)


def _params(cfg, seed=0):
    return init_params(jax.random.PRNGKey(seed), cfg)


def _run_engine(params, cfg, prompts, *, max_new=4, paged=True, **kw):
    eng = ServingEngine(params, cfg, paged=paged, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new=max_new))
    return {r.req_id: r.generated for r in eng.run()}, eng


def _refs(params, cfg, prompts, max_new=4):
    return [generate(params, cfg, jnp.asarray([p], jnp.int32),
                     max_new=max_new)[0, len(p):].tolist() for p in prompts]


# ---------------------------------------------------------------------------
# Greedy parity: paged == dense == generate(), every cache kind
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["gpt3-24l", "gemma3-12b", "rwkv6-7b"])
def test_paged_matches_dense_and_generate(arch):
    """Mixed prompt lengths straddling page (16) and chunk (4) boundaries,
    4 requests over 2 slots (slot + block recycle on the fly)."""
    cfg = _tiny_cfg() if arch == "gpt3-24l" else get_smoke_config(arch)
    params = _params(cfg)
    prompts = [[7], [1, 2, 3], [5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16,
                                17, 18, 19, 20, 21],
               [9, 8, 7, 6, 5, 4, 3, 2, 1]]
    kw = dict(slots=2, cache_len=64, chunk=4, page_size=16)
    dense, _ = _run_engine(params, cfg, prompts, paged=False, **kw)
    paged, _ = _run_engine(params, cfg, prompts, paged=True, **kw)
    refs = _refs(params, cfg, prompts)
    for i in range(len(prompts)):
        assert paged[i] == dense[i] == refs[i], (arch, i, paged[i], dense[i],
                                                 refs[i])


@pytest.mark.parametrize("chunk", [16, 80])
def test_paged_swa_ring_wrap_parity(chunk):
    """Prompt longer than the sliding window: the SWA ring pages wrap and
    recycle table columns mid-prefill; greedy output must equal both the
    dense ring engine and generate() for any chunk size."""
    cfg = get_smoke_config("gemma3-12b")          # window 64
    params = _params(cfg, 7)
    prompts = [[(i * 7 + 3) % cfg.vocab_size for i in range(80)]]
    kw = dict(slots=1, cache_len=128, chunk=chunk, page_size=16)
    dense, _ = _run_engine(params, cfg, prompts, max_new=6, paged=False, **kw)
    paged, _ = _run_engine(params, cfg, prompts, max_new=6, paged=True, **kw)
    refs = _refs(params, cfg, prompts, max_new=6)
    assert paged[0] == dense[0] == refs[0]


def test_paged_mla_latent_pool_parity():
    """DeepSeek-V3 MLA: paged latent pool through both the naive prefill
    gather and the absorbed page-wise decode.  MoE capacity dropping is
    per-call-batch-dependent, so admission is shape-identical to
    generate()'s prefill (slots=1, chunk >= prompt) — isolating the paged
    latent machinery (same caveat as the dense engine test)."""
    cfg = get_smoke_config("deepseek-v3-671b")
    params = _params(cfg)
    for p in [[1, 2, 3], [5, 6, 7, 8, 9], [9, 8, 7, 6, 5, 4, 3, 2, 1]]:
        done, _ = _run_engine(params, cfg, [p], slots=1, cache_len=64,
                              chunk=len(p), page_size=4)
        ref = _refs(params, cfg, [p])[0]
        assert done[0] == ref, (p, done[0], ref)


def test_paged_hybrid_ssm_state_stays_per_slot():
    """Jamba (Mamba + attention + MoE): paged KV pools coexist with
    per-slot recurrent state; parity vs the dense engine (whole-prompt
    admits sidestep the MoE chunking caveat)."""
    cfg = get_smoke_config("jamba-1.5-large-398b")
    params = _params(cfg)
    prompts = [[1, 2, 3], [5, 6, 7, 8, 9]]
    kw = dict(slots=2, cache_len=64, chunk=64, page_size=16)
    dense, _ = _run_engine(params, cfg, prompts, paged=False, **kw)
    paged, _ = _run_engine(params, cfg, prompts, paged=True, **kw)
    refs = _refs(params, cfg, prompts)
    for i in range(len(prompts)):
        assert paged[i] == dense[i] == refs[i]


def test_paged_late_arrival_heterogeneous_lengths():
    """A long and a short request decode concurrently; a third arrives
    mid-decode and is admitted into recycled pages."""
    cfg = _tiny_cfg()
    params = _params(cfg, 1)
    eng = ServingEngine(params, cfg, slots=2, cache_len=64, chunk=4,
                        paged=True, page_size=8)
    eng.submit(Request(0, list(range(1, 20)), max_new=8))
    eng.submit(Request(1, [9, 8], max_new=3))
    ticks = 0
    while eng.tick():
        ticks += 1
        if ticks == 2:
            eng.submit(Request(2, [4, 5, 6, 7], max_new=4))
    done = {r.req_id: r.generated for r in eng.finished}
    for rid, p in [(0, list(range(1, 20))), (1, [9, 8]), (2, [4, 5, 6, 7])]:
        ref = generate(params, cfg, jnp.asarray([p], jnp.int32),
                       max_new=len(done[rid]))[0, len(p):].tolist()
        assert done[rid] == ref, (rid, done[rid], ref)


# ---------------------------------------------------------------------------
# Allocator + pool hygiene
# ---------------------------------------------------------------------------

def test_block_allocator_accounting():
    a = BlockAllocator(4)
    assert a.n_free == 4 and a.reserved == 0
    assert a.reserve(3)
    assert not a.reserve(2)            # 4 - 3 < 2
    assert a.reserve(1)
    b0, b1 = a.alloc_one(), a.alloc_one()
    assert a.n_free == 2 and a.reserved == 2
    a.free([b0], unreserve=1)          # one page back + unused reservation
    assert a.n_free == 3 and a.reserved == 1
    with pytest.raises(AssertionError, match="double free"):
        a.free([b0])
    a.free([b1], unreserve=1)
    assert a.n_free == 4 and a.reserved == 0


def test_pool_exhaustion_backpressures_not_crashes():
    """Pool too small for both requests at once: the second waits in the
    queue (stats['backpressure'] ticks) and both finish correct."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    eng = ServingEngine(params, cfg, slots=2, cache_len=64, chunk=4,
                        paged=True, page_size=8, num_blocks=3)
    eng.submit(Request(0, [1, 2, 3, 4, 5, 6], max_new=8))     # 2 pages
    eng.submit(Request(1, [9, 8, 7, 6, 5], max_new=4))        # 2 pages
    done = {r.req_id: r.generated for r in eng.run()}
    assert eng.stats["backpressure"] > 0
    for rid, (p, mn) in {0: ([1, 2, 3, 4, 5, 6], 8),
                         1: ([9, 8, 7, 6, 5], 4)}.items():
        ref = generate(params, cfg, jnp.asarray([p], jnp.int32),
                       max_new=mn)[0, len(p):].tolist()
        assert done[rid] == ref, (rid, done[rid], ref)


def test_impossible_request_rejected_at_submit():
    cfg = _tiny_cfg()
    params = _params(cfg)
    eng = ServingEngine(params, cfg, slots=1, cache_len=64, chunk=4,
                        paged=True, page_size=8, num_blocks=2)
    with pytest.raises(ValueError, match="cache pages"):
        eng.submit(Request(0, list(range(1, 30)), max_new=4))  # 33 tok > 16
    assert not eng.queue


def test_block_recycle_is_scrubbed():
    """A recycled block must come back with zeroed K/V and positions -1 —
    stale positions from the previous owner could pass the causal mask."""
    cfg = _tiny_cfg()
    caches = init_cache(cfg, 2, 64, paged=True, page_size=8, num_blocks=6)
    def fill(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        return jnp.zeros_like(leaf) + (3 if name == "pos" else 1)
    caches = jax.tree_util.tree_map_with_path(fill, caches)
    blocks = jnp.asarray([1, 4, 6, 6], jnp.int32)   # 6 = out-of-pool pad
    cleared = make_clear_blocks(cfg)(caches, blocks,
                                     jnp.asarray([0], jnp.int32))

    def check(path, before, after):
        name = str(getattr(path[-1], "key", path[-1]))
        top = str(getattr(path[0], "key", path[0]))
        bdim = 1 if top == "stack" else 0
        b, a = np.asarray(before), np.asarray(after)
        want = -1 if name == "pos" else 0
        sl = (slice(None),) * bdim
        assert (a[sl + ([1, 4],)] == want).all(), (path,)
        np.testing.assert_array_equal(a[sl + ([0, 2, 3, 5],)],
                                      b[sl + ([0, 2, 3, 5],)],
                                      err_msg=f"{path}: untouched blocks")
    jax.tree_util.tree_map_with_path(check, caches, cleared)


def test_slot_reuse_through_recycled_blocks():
    """slots=1, pool exactly one request wide: the second request MUST run
    on the first one's recycled blocks and still match generate()."""
    cfg = _tiny_cfg()
    params = _params(cfg, 2)
    eng = ServingEngine(params, cfg, slots=1, cache_len=32, chunk=4,
                        paged=True, page_size=8, num_blocks=2)
    eng.submit(Request(0, [5, 6, 7, 8, 9, 10, 11], max_new=4))
    eng.submit(Request(1, [1, 2, 3], max_new=4))
    done = {r.req_id: r.generated for r in eng.run()}
    ref = generate(params, cfg, jnp.asarray([[1, 2, 3]], jnp.int32),
                   max_new=4)[0, 3:].tolist()
    assert done[1] == ref, (done[1], ref)


def test_paged_pool_serves_more_concurrency_at_equal_memory():
    """The point of paging: at the same cache memory, heterogeneous
    requests overlap more.  Dense: 3 slots × worst-case 64 = 192 entries.
    Paged: same 192 entries as 24 pages of 8 — short requests take 1-2
    pages, so >3 run concurrently."""
    cfg = _tiny_cfg()
    params = _params(cfg, 3)
    long_p, short_p = list(range(1, 49)), [7, 8, 9]
    reqs = [(long_p, 16)] + [(short_p, 8)] * 6
    peak = {}
    for paged, slots in [(False, 3), (True, 7)]:
        eng = ServingEngine(params, cfg, slots=slots, cache_len=64, chunk=16,
                            paged=paged, page_size=8,
                            num_blocks=24 if paged else None)
        for i, (p, mn) in enumerate(reqs):
            eng.submit(Request(i, p, max_new=mn))
        peak[paged] = 0
        while True:
            n = eng.tick()
            if not n and not eng.queue:
                break
            peak[paged] = max(peak[paged], n)
    assert peak[True] > peak[False], peak
    assert peak[False] <= 3 and peak[True] >= 5, peak


# ---------------------------------------------------------------------------
# Per-slot in-jit sampling
# ---------------------------------------------------------------------------

def test_greedy_slots_bitwise_stable_next_to_sampled():
    """temperature=0 slots must be bitwise-identical to the all-greedy
    engine even when a sampled request shares the batch."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    g, _ = _run_engine(params, cfg, [[1, 2, 3]], max_new=6, paged=False,
                       slots=2, cache_len=64, chunk=4)
    eng = ServingEngine(params, cfg, slots=2, cache_len=64, chunk=4,
                        paged=True, page_size=16)
    eng.submit(Request(0, [1, 2, 3], max_new=6))
    eng.submit(Request(1, [4, 5, 6], max_new=6, temperature=1.5, top_p=0.9))
    done = {r.req_id: r.generated for r in eng.run()}
    assert done[0] == g[0], (done[0], g[0])
    assert all(0 <= t < cfg.vocab_size for t in done[1])


def test_sampling_deterministic_given_seed():
    cfg = _tiny_cfg()
    params = _params(cfg)
    outs = []
    for _ in range(2):
        eng = ServingEngine(params, cfg, slots=1, cache_len=64, chunk=4,
                            paged=True, seed=11)
        eng.submit(Request(0, [4, 5, 6], max_new=8, temperature=1.0,
                           top_p=0.8))
        outs.append(eng.run()[0].generated)
    assert outs[0] == outs[1]
    eng = ServingEngine(params, cfg, slots=1, cache_len=64, chunk=4,
                        paged=True, seed=12)
    eng.submit(Request(0, [4, 5, 6], max_new=8, temperature=1.0, top_p=0.8))
    assert eng.run()[0].generated != outs[0]   # seed actually matters


def test_finished_sampled_slot_resets_to_greedy_defaults():
    """A finished sampled request must hand its slot back with greedy
    defaults — otherwise an idle slot keeps the all-greedy lax.cond fast
    path switched off forever (and later greedy occupants stay bitwise
    regardless)."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    eng = ServingEngine(params, cfg, slots=2, cache_len=64, chunk=4,
                        paged=True)
    eng.submit(Request(0, [1, 2, 3], max_new=2, temperature=1.0, top_p=0.7))
    eng.run()
    assert float(eng._temp.max()) == 0.0 and float(eng._topp.min()) == 1.0
    eng.submit(Request(1, [4, 5, 6], max_new=4))
    out = eng.run()[1].generated
    fresh = ServingEngine(params, cfg, slots=2, cache_len=64, chunk=4,
                          paged=True)
    fresh.submit(Request(9, [4, 5, 6], max_new=4))
    assert out == fresh.run()[0].generated


def test_top_p_zero_degenerates_to_greedy():
    cfg = _tiny_cfg()
    params = _params(cfg)
    g, _ = _run_engine(params, cfg, [[1, 2, 3]], max_new=6, paged=False,
                       slots=1, cache_len=64, chunk=4)
    eng = ServingEngine(params, cfg, slots=1, cache_len=64, chunk=4)
    eng.submit(Request(0, [1, 2, 3], max_new=6, temperature=1.0, top_p=1e-9))
    assert eng.run()[0].generated == g[0]


def test_top_k_one_degenerates_to_greedy():
    cfg = _tiny_cfg()
    params = _params(cfg)
    g, _ = _run_engine(params, cfg, [[1, 2, 3]], max_new=6, paged=False,
                       slots=1, cache_len=64, chunk=4)
    eng = ServingEngine(params, cfg, slots=1, cache_len=64, chunk=4,
                        paged=True)
    eng.submit(Request(0, [1, 2, 3], max_new=6, temperature=2.0, top_k=1))
    assert eng.run()[0].generated == g[0]


def test_top_k_restricts_support():
    """Partial top-k (1 < k < V) must confine sampling to the k
    highest-logit tokens, and disabled top-k (0) must reach beyond
    them."""
    from repro.serve.engine import topp_sample
    V, B = 32, 256
    # descending logits: the top-k set is exactly {0, ..., k-1}
    logits = jnp.tile(jnp.linspace(3.0, -3.0, V)[None], (B, 1))
    keys = np.stack([np.arange(B, dtype=np.uint32),
                     np.zeros(B, np.uint32)], axis=-1)
    temp = jnp.full((B,), 5.0)            # flat enough to leave the top
    topp = jnp.ones((B,))
    for k in (2, 5):
        toks = topp_sample(jnp.asarray(keys), logits, temp, topp,
                           jnp.full((B,), k, jnp.int32))
        support = set(np.asarray(toks).ravel().tolist())
        assert support <= set(range(k)), (k, sorted(support))
        assert len(support) > 1, "top-k should still sample, not argmax"
    toks = topp_sample(jnp.asarray(keys), logits, temp, topp,
                       jnp.zeros((B,), jnp.int32))
    assert np.asarray(toks).max() >= 5    # 0 = disabled: full support


def test_repetition_penalty_discourages_repeats():
    """Greedy + a large penalty: every emitted token must be new (the
    finite-vocab argmax always has an unseen candidate to prefer over a
    crushed seen logit on this random tiny model)."""
    cfg = _tiny_cfg()
    params = _params(cfg, 5)
    prompt = [3, 1, 4]
    eng = ServingEngine(params, cfg, slots=1, cache_len=64, chunk=4,
                        paged=True)
    eng.submit(Request(0, prompt, max_new=10, rep_penalty=1e9))
    out = eng.run()[0].generated
    emitted = list(prompt) + out
    assert len(set(emitted)) == len(emitted), emitted
    # and the unpenalized greedy chain DOES repeat (the penalty did work)
    ref, _ = _run_engine(params, cfg, [prompt], max_new=10, paged=True,
                         slots=1, cache_len=64, chunk=4)
    base = list(prompt) + ref[0]
    assert len(set(base)) < len(base), base


def test_repetition_penalty_slot_isolated_and_bitwise_neutral():
    """A penalized slot must not perturb the greedy slot sharing the
    batch (the lax.cond penalty branch rewrites only rows with
    penalty != 1), and its seen-mask must reset with the slot."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    g, _ = _run_engine(params, cfg, [[1, 2, 3]], max_new=6, paged=True,
                       slots=2, cache_len=64, chunk=4)
    eng = ServingEngine(params, cfg, slots=2, cache_len=64, chunk=4,
                        paged=True)
    eng.submit(Request(0, [1, 2, 3], max_new=6))
    eng.submit(Request(1, [4, 5, 6], max_new=6, rep_penalty=2.0))
    done = {r.req_id: r.generated for r in eng.run()}
    assert done[0] == g[0], (done[0], g[0])
    # slot handed back with greedy defaults; a follow-up greedy request
    # in the same engine matches a fresh engine (seen-mask cleared)
    assert float(eng._reppen.max()) == 1.0
    eng.submit(Request(2, [1, 2, 3], max_new=6))
    out2 = eng.run()[-1].generated
    assert out2 == g[0], (out2, g[0])
