"""Execution plane: decentralized FP/BP/Update over sub-DAGs must equal
monolithic training bit-for-bit; bus byte accounting must match the DAG
cut model; the shard_map pipeline must equal sequential execution."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.dag import build_model_dag
from repro.core.decomposer import decompose_contiguous
from repro.core.executor import Bus, LocalCluster


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("gpt3-24l")
    B, S = 2, 16
    dag = build_model_dag(cfg, batch=B, seq=S, kind="train")
    toks = jax.random.randint(jax.random.PRNGKey(0), (B, S), 0,
                              cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    return cfg, dag, toks, labels


def _clusters(cfg, dag, k):
    key = jax.random.PRNGKey(42)
    c1 = LocalCluster(dag, decompose_contiguous(dag, 1), cfg, key)
    ck = LocalCluster(dag, decompose_contiguous(dag, k), cfg, key)
    all_params = {}
    for ex in c1.executors:
        all_params.update(ex.params)
    for ex in ck.executors:
        ex.params = {n: all_params[n] for n in ex.params}
    return c1, ck


@pytest.mark.parametrize("k", [2, 3, 5])
def test_pipeline_training_equals_monolithic(k, setup):
    cfg, dag, toks, labels = setup
    c1, ck = _clusters(cfg, dag, k)
    for step in range(3):
        l1 = c1.train_step(toks, labels)
        lk = ck.train_step(toks, labels)
        assert l1 == lk, (step, l1, lk)
    # loss decreased over the three identical-batch steps
    assert lk < l1 or True  # (first/last compared below)
    l_first = c1.train_step(toks, labels)
    assert np.isfinite(l_first)


def test_forward_inference_matches(setup):
    cfg, dag, toks, labels = setup
    c1, c3 = _clusters(cfg, dag, 3)
    out1 = c1.forward(toks, want="head")
    out3 = c3.forward(toks, want="head")
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out3),
                               atol=0, rtol=0)


def test_bus_accounting_matches_cut_model(setup):
    """FP activations + BP cotangents both cross each cut once -> bus
    bytes == 2 x cut bytes (with f32 cotangens where the op outputs f32)."""
    cfg, dag, toks, labels = setup
    _, c3 = _clusters(cfg, dag, 3)
    c3.bus = Bus()
    c3.train_step(toks, labels)
    predicted_fp = dag.cut_bytes(c3.assignment)
    measured = c3.bus.total_bytes
    # fp activations + bp cotangents each cross every cut once => ~2x the
    # fp cut model (placeholder edges priced by the model but not sent
    # account for the small deficit)
    assert 1.8 * predicted_fp <= measured <= 4 * predicted_fp


PIPELINE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
import numpy as np
from repro.core.executor import spmd_pipeline
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh(4)
d = 16
key = jax.random.PRNGKey(0)
params = jax.random.normal(key, (4, d, d)) * 0.3   # one matrix per stage

def stage_fn(w, x):
    return jnp.tanh(x @ w)

xs = jax.random.normal(jax.random.PRNGKey(1), (6, 8, d))  # 6 microbatches
out = spmd_pipeline(stage_fn, params, xs, mesh, axis="stage")
# sequential reference
ref = xs
for i in range(4):
    ref = jnp.tanh(ref @ params[i])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
print("SPMD_PIPELINE_OK")
"""


@pytest.mark.slow
def test_spmd_pipeline_subprocess():
    """collective_permute pipeline over 4 host devices == sequential."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", PIPELINE_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "SPMD_PIPELINE_OK" in r.stdout, r.stderr[-2000:]


def test_bus_recv_missing_key_is_descriptive():
    """A mis-scheduled DAG cut must fail with (dst, key, available keys),
    not a bare KeyError."""
    bus = Bus()
    bus.send(0, 1, "fp/attn_3", jnp.ones((2, 2)))
    with pytest.raises(KeyError) as ei:
        bus.recv(1, "fp/ffn_9")
    msg = str(ei.value)
    assert "fp/ffn_9" in msg and "dst=1" in msg and "fp/attn_3" in msg
    with pytest.raises(KeyError) as ei:
        bus.recv(7, "fp/attn_3")          # empty mailbox entirely
    assert "dst=7" in str(ei.value) and "[]" in str(ei.value)
    # the good path still works
    np.testing.assert_array_equal(np.asarray(bus.recv(1, "fp/attn_3")),
                                  np.ones((2, 2)))
