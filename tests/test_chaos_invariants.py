"""Chaos property test: random seeded FaultPlans over a 3-replica fleet.

For any fault schedule the degraded-mode router must uphold four
invariants: (1) no request is ever dropped or duplicated — every
submitted req_id shows up exactly once across completed + failed;
(2) every request ends in a TERMINAL structured outcome (completed ones
"ok", failed ones one of the failure outcomes, traces covering all);
(3) whatever completes is bitwise-identical to a no-fault reference run
— crashes, stragglers, partitions, pool pressure, preemption, state
migration, snapshot-resume and ``corrupt``-flipped transfers may move
work around, re-prefill or re-import it, but they must never change
what a finished request generated; (4) unverified content is never
served — every migration the routers count as successful was imported
verified, and every checksum rejection fell back to
requeue-from-prompt (seeded plans draw ``corrupt`` faults too, so
flipped payloads actually occur).

Runs under real ``hypothesis`` when installed (requirements-dev.txt);
falls back to the deterministic ``tests/_hypothesis_shim.py`` on a bare
environment.
"""
import dataclasses

import jax

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    _SETTINGS = dict(max_examples=5, deadline=None,
                     suppress_health_check=list(HealthCheck))
except ImportError:  # bare env: deterministic fallback, see the shim
    from _hypothesis_shim import given, settings, st
    _SETTINGS = dict(max_examples=5)

from repro.configs import get_smoke_config
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServingEngine
from repro.serve.faults import FaultPlan
from repro.serve.router import OUTCOMES, FleetRouter

_N_REQ = 5
_MAX_NEW = 5
_cache: dict = {}


def _tiny():
    if "params" not in _cache:
        cfg = dataclasses.replace(get_smoke_config("gpt3-24l"),
                                  vocab_size=128, d_model=128, d_ff=256,
                                  n_heads=4, n_kv_heads=4, head_dim=32)
        _cache["cfg"] = cfg
        _cache["params"] = init_params(jax.random.PRNGKey(0), cfg)
    return _cache["params"], _cache["cfg"]


def _engine():
    params, cfg = _tiny()
    return ServingEngine(params, cfg, slots=2, cache_len=64, chunk=8,
                         paged=True, page_size=16)


def _requests():
    _, cfg = _tiny()
    return [Request(i, [(3 + 5 * i + j) % cfg.vocab_size
                        for j in range(4 + i % 3)], max_new=_MAX_NEW)
            for i in range(_N_REQ)]


def _fleet(plan=None):
    return FleetRouter(
        [(_engine(), d) for d in ("rtx4090", "rtx3080", "rtx3080")],
        standby=[(_engine(), "rtx3080")],
        fault_plan=plan, partition_timeout=8, hol_patience=4,
        snapshot_every=4, rebalance_every=6)


def _reference():
    """No-fault run over the canonical workload, computed once."""
    if "ref" not in _cache:
        router = _fleet()
        for r in _requests():
            router.submit(r)
        res = router.run()
        assert sorted(r.req_id for r in res.completed) == list(range(_N_REQ))
        _cache["ref"] = {r.req_id: list(r.generated) for r in res.completed}
    return _cache["ref"]


@settings(**_SETTINGS)
@given(st.integers(0, 10_000))
def test_chaos_invariants(seed):
    ref = _reference()
    plan = FaultPlan.seeded(seed, ticks=30, replica_ids=[0, 1, 2, 3],
                            rate=0.12)
    router = _fleet(plan)
    for r in _requests():
        router.submit(r)
    res = router.run(max_ticks=500)
    # (1) nothing dropped, nothing duplicated
    ids = sorted([r.req_id for r in res.completed]
                 + [r.req_id for r in res.failed])
    assert ids == list(range(_N_REQ)), \
        f"plan={plan!r}: terminal ids {ids}"
    # (2) every outcome terminal and structured; traces cover everyone
    for r in res.completed:
        assert r.outcome == "ok"
    for r in res.failed:
        assert r.outcome in OUTCOMES and r.outcome != "ok"
        assert r.retries <= r.max_retries + 1
    assert set(res.traces) == set(range(_N_REQ))
    for rid, tr in res.traces.items():
        assert tr["outcome"] is not None
    # (3) completed work is bitwise-identical to the no-fault run,
    # wherever faults moved it and however often it re-prefilled,
    # migrated mid-decode, or resumed from a router snapshot
    for r in res.completed:
        assert list(r.generated) == ref[r.req_id], \
            f"plan={plan!r}: req {r.req_id} diverged"
    # (4) never serve unverified pages: successful migrations all passed
    # the importer's checksum chain, and every rejection (corrupt flips
    # included) became a requeue-from-prompt fallback, not an import
    reps = router.replicas + list(router._standby.values())
    rejects = sum(r.engine.stats["import_rejects"] for r in reps)
    imports = sum(r.engine.stats["imported"] for r in reps)
    assert imports == (router.stats["migrations"]
                       + router.stats["rebalance_holds"]), \
        f"plan={plan!r}: imports {imports} != " \
        f"migrations {router.stats['migrations']} " \
        f"+ holds {router.stats['rebalance_holds']}"
    assert rejects <= router.stats["migration_fallbacks"], \
        f"plan={plan!r}: rejects {rejects} exceed fallbacks"
