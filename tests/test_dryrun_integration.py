"""End-to-end dry-run integration: lower+compile one (arch × shape) pair
on the 256-chip production mesh in a subprocess (XLA_FLAGS isolation) and
check the recorded roofline artifact."""
import json
import os
import subprocess
import sys
import tempfile

import pytest


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [("musicgen-medium", "decode_32k"),
                                        ("rwkv6-7b", "long_500k")])
def test_dryrun_pair_subprocess(arch, shape):
    # these two pairs were seed failures: compiled.cost_analysis() comes
    # back list-wrapped for their programs on this jax version; dryrun
    # unwraps it since PR 2
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    with tempfile.TemporaryDirectory() as d:
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", arch, "--shape", shape, "--out", d],
            env=env, capture_output=True, text=True, timeout=900)
        assert r.returncode == 0, r.stderr[-2000:]
        path = os.path.join(d, f"{arch}_{shape}_pod16x16.json")
        rec = json.load(open(path))
        assert rec["status"] == "ok", rec.get("error")
        assert rec["chips"] == 256
        ro = rec["roofline"]
        assert ro["compute_s"] >= 0 and ro["memory_s"] > 0
        assert ro["bottleneck"] in ("compute", "memory", "collective")
        assert rec["per_chip_arg_bytes"] > 0
        # decode steps must fit v5e HBM comfortably
        assert rec["per_chip_arg_bytes"] < 16e9


def test_baseline_matrix_definition():
    from repro.configs import baseline_pairs
    pairs, skips = baseline_pairs()
    assert len(pairs) == 33 and len(skips) == 7
    longs = [p for p in pairs if p[1] == "long_500k"]
    assert sorted(a for a, _ in longs) == [
        "gemma3-12b", "jamba-1.5-large-398b", "rwkv6-7b"]
