"""Broker failover accounting regressions (paper §3.2/§3.8).

The seed broker drafted backups by comparing a backup's SPEED (FLOP/s)
against the dead node's LOAD (seconds) — dimensionally nonsense that
always picked the slowest backup — left dead nodes' entries in
``Schedule.loads`` (so makespan counted corpses), and threw away the
survivors' existing loads when rescheduling with an empty backup pool.
``schedule_pipeline`` additionally mapped stage i to ``nodes[i % n]``
blind to memory.  These tests pin the fixed semantics: speed-matched
drafting, truthful post-churn loads/makespan, load-seeded rebalance,
feasibility-aware pipeline mapping, and deterministic seeded churn sims.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.broker import Broker
from repro.core.dag import build_model_dag
from repro.core.perfmodel import (DEVICE_CATALOG, GB, LINK_REGIMES, CompNode,
                                  DeviceSpec, make_fleet)
from repro.core.scheduler import (Task, schedule_loadbalance,
                                  schedule_pipeline)

LINK = LINK_REGIMES["wan_1gbps"]


def _node(dev: str, reliability: float = 1.0) -> CompNode:
    return CompNode(-1, DEVICE_CATALOG[dev], LINK, reliability=reliability)


def _bert_dag():
    return build_model_dag(get_config("bert-large"), batch=8, seq=128)


def _mixed_broker():
    """2 actives (one slow rtx3080, one fast a100) + one backup of each
    speed class, explicitly pooled."""
    broker = Broker(seed=0)
    ids = {}
    ids["slow"] = broker.register(_node("rtx3080"), pool="active")
    ids["fast"] = broker.register(_node("a100"), pool="active")
    ids["slow_backup"] = broker.register(_node("rtx3080"), pool="backup")
    ids["fast_backup"] = broker.register(_node("a100"), pool="backup")
    broker.submit_job(_bert_dag(), n_parts=2)
    return broker, ids


# ---------------------------------------------------------------------------
# Satellite 1: replacement drafting matches SPEED, not load-seconds
# ---------------------------------------------------------------------------

def test_slow_dead_node_drafts_slow_backup():
    broker, ids = _mixed_broker()
    broker.quit(ids["slow"], graceful=False)
    assert ids["slow_backup"] in broker.active
    assert ids["fast_backup"] in broker.backup
    # the dead node's tasks all moved to the drafted peer
    assert ids["slow"] not in set(broker.schedule.assignment.values())


def test_fast_dead_node_drafts_fast_backup():
    """The regression case: loads are O(seconds), so the seed's
    |speed - load| metric always drafted the SLOWEST backup — killing
    the fast node must draft the fast backup, not an arbitrary one."""
    broker, ids = _mixed_broker()
    broker.quit(ids["fast"], graceful=False)
    assert ids["fast_backup"] in broker.active
    assert ids["slow_backup"] in broker.backup


def test_speed_record_survives_node_death():
    broker, ids = _mixed_broker()
    dead_speed = broker.active[ids["fast"]].speed
    broker.quit(ids["fast"], graceful=False)
    # the node object is popped, but its speed record remains for drafting
    assert broker.speeds[ids["fast"]] == dead_speed


# ---------------------------------------------------------------------------
# Satellite 2: loads stay truthful after churn
# ---------------------------------------------------------------------------

def test_dead_node_load_entry_removed():
    broker, ids = _mixed_broker()
    assert ids["slow"] in broker.schedule.loads
    broker.quit(ids["slow"], graceful=False)
    assert ids["slow"] not in broker.schedule.loads
    # makespan is now the max over LIVE nodes only
    assert set(broker.schedule.loads) <= set(broker.active)
    assert broker.schedule.makespan == max(broker.schedule.loads.values())


def test_loads_match_assignment_after_replacement():
    """After draft-and-remap, every node's load equals the recomputed
    sum of its assigned tasks' times (no stale or double-counted
    entries)."""
    broker, ids = _mixed_broker()
    broker.quit(ids["fast"], graceful=False)
    for nid, node in broker.active.items():
        expect = sum(broker.tasks[tid].flops / node.speed
                     for tid, anid in broker.schedule.assignment.items()
                     if anid == nid)
        assert broker.schedule.loads.get(nid, 0.0) == pytest.approx(expect)


def test_empty_backup_reschedule_seeds_and_merges_loads():
    """Backup pool empty: the rebalance must see survivors' EXISTING
    loads (not pretend they are idle) and merge its result back so
    makespan stays truthful."""
    broker = Broker(backup_fraction=0.0, seed=3)
    for _ in range(4):
        broker.register(_node("rtx3080"), pool="active")
    broker.submit_job(_bert_dag(), n_parts=4)
    victims = [nid for nid in broker.schedule.assignment.values()][:1]
    broker.quit(victims[0], graceful=False)
    assert victims[0] not in broker.schedule.loads
    assert set(broker.schedule.assignment.values()) <= set(broker.active)
    for nid, node in broker.active.items():
        expect = sum(broker.tasks[tid].flops / node.speed
                     for tid, anid in broker.schedule.assignment.items()
                     if anid == nid)
        assert broker.schedule.loads.get(nid, 0.0) == pytest.approx(expect)


def test_init_used_blocks_overcommitted_peer():
    """Memory commitments survive a reschedule too: a survivor whose GPU
    is nearly full from tasks it already holds must not be handed more
    than it can fit, even if it is the less-loaded peer."""
    a = CompNode(0, DeviceSpec("a", 100.0, gpu_mem=10 * GB), LINK)
    b = CompNode(1, DeviceSpec("b", 100.0, gpu_mem=10 * GB), LINK)
    task = Task(0, ("op",), flops=1e12, gpu_bytes=4 * GB)
    sched = schedule_loadbalance(
        [task], [a, b],
        init_loads={0: 0.0, 1: 50.0},          # a looks idle...
        init_used={0: [8 * GB, 0.0, 0.0]})     # ...but its memory is full
    assert sched.feasible
    assert sched.assignment[0] == 1            # only b can actually fit it


def test_init_loads_steers_rebalance_to_less_loaded_peer():
    a = CompNode(0, DEVICE_CATALOG["rtx3080"], LINK)
    b = CompNode(1, DEVICE_CATALOG["rtx3080"], LINK)
    task = Task(0, ("op",), flops=1e12, gpu_bytes=GB)
    sched = schedule_loadbalance([task], [a, b],
                                 init_loads={0: 100.0, 1: 0.0})
    assert sched.assignment[0] == 1            # idle peer wins
    assert sched.loads[0] == pytest.approx(100.0)   # seed merged through
    assert sched.makespan == pytest.approx(100.0)


# ---------------------------------------------------------------------------
# Satellite 3: schedule_pipeline memory feasibility
# ---------------------------------------------------------------------------

def test_pipeline_skips_memory_infeasible_peer():
    """Stage 0 prefers the fastest peer but does not fit its memory: it
    must SKIP to the next feasible peer (and the schedule stays
    feasible), not blindly map and flip the flag."""
    thin = CompNode(0, DeviceSpec("thin", 100.0, gpu_mem=1 * GB), LINK)
    fat = CompNode(1, DeviceSpec("fat", 10.0, gpu_mem=64 * GB), LINK)
    big = Task(0, ("s0",), flops=1e12, gpu_bytes=8 * GB)
    small = Task(1, ("s1",), flops=1e12, gpu_bytes=0.5 * GB)
    sched = schedule_pipeline([big, small], [thin, fat])
    assert sched.feasible
    assert sched.assignment[0] == fat.node_id      # skipped past thin
    assert sched.assignment[1] == fat.node_id      # start index 1 = fat


def test_pipeline_memory_use_is_cumulative():
    """Two stages that each fit a peer alone but not together: the
    second must move on instead of overcommitting the peer."""
    n0 = CompNode(0, DeviceSpec("a", 100.0, gpu_mem=1 * GB), LINK)
    n1 = CompNode(1, DeviceSpec("b", 100.0, gpu_mem=1 * GB), LINK)
    s0 = Task(0, ("s0",), flops=1e12, gpu_bytes=0.7 * GB)
    s1 = Task(1, ("s1",), flops=1e12, gpu_bytes=0.7 * GB)
    s2 = Task(2, ("s2",), flops=1e12, gpu_bytes=0.7 * GB)
    sched = schedule_pipeline([s0, s1, s2], [n0, n1])
    # s0 -> n0, s1 -> n1; s2 wraps to n0 but 1.4GB > 1GB on BOTH peers
    assert not sched.feasible
    assert sched.assignment[0] != sched.assignment[1]


def test_pipeline_infeasible_only_when_no_peer_fits():
    n0 = CompNode(0, DeviceSpec("a", 100.0, gpu_mem=1 * GB), LINK)
    huge = Task(0, ("s0",), flops=1e12, gpu_bytes=100 * GB)
    sched = schedule_pipeline([huge], [n0])
    assert not sched.feasible
    assert sched.assignment[0] == 0                # still force-placed


# ---------------------------------------------------------------------------
# Seeded churn sims: invariants hold through quit/replace/reschedule
# ---------------------------------------------------------------------------

def _churn_broker(seed, n=24, reliability=0.9):
    broker = Broker(backup_fraction=0.25, seed=seed)
    for node in make_fleet([("rtx3080", n // 2), ("rtx4090", n // 2)], LINK):
        node.reliability = reliability
        broker.register(node)
    broker.submit_job(_bert_dag(), n_parts=8)
    return broker


@pytest.mark.parametrize("seed", [11, 42])
def test_churn_loads_and_assignment_invariants(seed):
    broker = _churn_broker(seed)
    for _ in range(15):
        broker.heartbeat_round()
        if not broker.active:
            break
        # loads never reference a dead node, makespan stays finite + true
        assert set(broker.schedule.loads) <= set(broker.active)
        assert broker.schedule.makespan >= 0.0
        # every unfinished task sits on a live node
        assert all(nid in broker.active
                   for tid, nid in broker.schedule.assignment.items())
    replaced = sum(1 for e in broker.events if e.kind == "replace")
    failures = sum(1 for e in broker.events
                   if e.kind == "quit" and e.detail == "failure")
    assert failures > 0                         # the sim actually churns
    assert replaced > 0                         # and the backup pool works


def test_churn_sim_deterministic_and_all_assigned():
    results = []
    for _ in range(2):
        broker = _churn_broker(7)
        results.append(broker.run_sim(rounds=15))
    assert results[0] == results[1]
    assert results[0]["all_tasks_assigned"]
    assert results[0]["failures"] > 0


# ---------------------------------------------------------------------------
# Standbys are not immortal: the heartbeat pings the backup pool too
# ---------------------------------------------------------------------------

def test_heartbeat_pings_backup_pool():
    """The seed heartbeat only pinged actives, so a long-dead standby
    could be drafted as a replacement.  Backups now fail by the same
    seeded (1 - reliability) process and dead ones leave the pool."""
    broker = Broker(seed=0)
    broker.register(_node("a100", reliability=1.0), pool="active")
    doomed = broker.register(_node("rtx3080", reliability=0.0),
                             pool="backup")
    dead = broker.heartbeat_round()
    assert dead == [doomed]
    assert doomed not in broker.backup and not broker.backup
    assert broker.active[0].online                   # active untouched


def test_dead_backup_never_drafted():
    broker = Broker(seed=0)
    active = broker.register(_node("rtx3080", reliability=1.0),
                             pool="active")
    broker.register(_node("rtx3080", reliability=0.0), pool="backup")
    broker.submit_job(_bert_dag(), n_parts=1)
    broker.heartbeat_round()                 # the standby dies here
    assert not broker.backup
    # the active now fails with an unfinished task: there must be no
    # corpse left to draft — draft_backup reports the empty pool
    assert broker.draft_backup(active) is None
    broker.quit(active, graceful=False)
    assert all(e.kind != "replace" for e in broker.events)


def test_active_failure_outcomes_independent_of_backup_pool_size():
    """Actives draw from the seeded RNG before backups each round, so a
    given seed produces the same per-round active deaths whether or not
    standbys are registered."""
    def active_deaths(n_backup):
        broker = Broker(seed=5)
        ids = [broker.register(_node("rtx3080", reliability=0.9),
                               pool="active") for _ in range(6)]
        for _ in range(n_backup):
            broker.register(_node("rtx3080", reliability=1.0),
                            pool="backup")
        deaths = []
        for _ in range(10):
            deaths.append([nid for nid in broker.heartbeat_round()
                           if nid in ids])
        return deaths
    assert active_deaths(0) == active_deaths(3)
