"""Fused Pallas paged-decode attention tests.

Two layers of coverage:

* **kernel vs scan path** — ``repro.kernels.ops.paged_attention``
  against the chunked-gather reference in
  ``repro.models.layers.attention(..., table=...)`` across dtypes
  (f32/bf16), head dims, GQA ratios, softcap, SWA windows crossing page
  boundaries, ``-1``-padded table columns, the MLA second-contraction
  path, and idle (position ``-1``) slots;
* **engine under ``use_kernel=True``** — token-level parity with the
  scan-path paged engine, the dense engine and ``generate()`` on the
  KV / GQA / SWA / MLA / hybrid configs, plus a recycled-block scrub
  regression under the kernel path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.kernels import ops
from repro.models.layers import attention, swa_ring_blocks
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServingEngine, generate

KEY = jax.random.PRNGKey(3)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


def _pool_case(B, Hq, Hkv, D, page, n_cols, dtype, *, used_cols, seed=0,
               Dv=None, De=0):
    """Build a pool + table where each row has ``used_cols`` allocated
    pages (the rest are -1) and the last allocated page is PARTIALLY
    written — trailing entries keep position -1 like a real pool."""
    rng = np.random.RandomState(seed)
    Dv = Dv or D
    N = B * n_cols + 2                       # spare blocks stay unused
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    k = jax.random.normal(ks[0], (N, page, Hkv, D), dtype)
    v = jax.random.normal(ks[1], (N, page, Hkv, Dv), dtype)
    ke = jax.random.normal(ks[2], (N, page, Hkv, De), dtype) if De else None
    # positions: block b holds its logical page's positions, partially
    pos = np.full((N, page), -1, np.int32)
    table = np.full((B, n_cols), -1, np.int32)
    q_pos = np.zeros((B, 1), np.int32)
    perm = rng.permutation(N)      # one shared permutation -> rows get
    blk = 0                        # disjoint (scattered) pool blocks
    for b in range(B):
        t_total = used_cols * page - rng.randint(0, page)  # partial tail
        q_pos[b, 0] = t_total                              # next position
        for c in range(used_cols):
            table[b, c] = perm[blk]
            lo, hi = c * page, min((c + 1) * page, t_total)
            if hi > lo:
                pos[perm[blk], : hi - lo] = np.arange(lo, hi)
            blk += 1
    q = jax.random.normal(ks[3], (B, 1, Hq, D), dtype)
    qe = jax.random.normal(ks[4], (B, 1, Hq, De), dtype) if De else None
    return (q, k, v, jnp.asarray(pos), jnp.asarray(table),
            jnp.asarray(q_pos), qe, ke)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,D,page,n_cols,used,window,softcap",
    [(2, 4, 2, 32, 8, 4, 3, 0, 0.0),      # GQA, -1 tail columns
     (1, 8, 8, 64, 16, 4, 4, 0, 0.0),     # MHA, full table
     (3, 4, 1, 80, 8, 6, 2, 0, 0.0),      # MQA, odd head dim
     (2, 4, 2, 32, 8, 8, 5, 20, 0.0),     # SWA window crossing pages
     (1, 4, 2, 32, 8, 4, 3, 0, 30.0),     # gemma-style softcap
     (2, 16, 4, 128, 16, 3, 3, 0, 0.0)])  # wide heads, MXU-aligned
def test_paged_kernel_vs_scan(B, Hq, Hkv, D, page, n_cols, used, window,
                              softcap, dtype):
    q, k, v, pos, table, q_pos, _, _ = _pool_case(
        B, Hq, Hkv, D, page, n_cols, dtype, used_cols=used, seed=B + used)
    out = ops.paged_attention(q, k, v, pos, table, q_pos, window=window,
                              softcap=softcap)
    exp = attention(q, k, v, q_pos, pos, window=window, softcap=softcap,
                    table=table, kv_chunk=page)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_kernel_mla_second_contraction(dtype):
    """MLA absorbed decode: latent pool is both K and V (Dv == D == kr),
    the rope pool enters as q_extra/k_extra."""
    B, H, kr, dr, page, n_cols = 2, 4, 48, 16, 8, 4
    q, k, _, pos, table, q_pos, qe, ke = _pool_case(
        B, H, 1, kr, page, n_cols, dtype, used_cols=3, seed=11, De=dr)
    scale = (kr + dr) ** -0.5
    out = ops.paged_attention(q, k, k, pos, table, q_pos, scale=scale,
                              q_extra=qe, k_extra=ke)
    exp = attention(q, k, k, q_pos, pos, scale=scale, q_extra=qe,
                    k_extra=ke, table=table, kv_chunk=page)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_paged_kernel_idle_slot_outputs_zero():
    """Rows with q_pos -1 (idle serving slots) must produce exactly-zero
    output, like the scan path (all keys masked -> l == 0)."""
    q, k, v, pos, table, q_pos, _, _ = _pool_case(
        2, 4, 2, 32, 8, 4, jnp.float32, used_cols=3, seed=5)
    q_pos = q_pos.at[1, 0].set(-1)
    table = table.at[1].set(-1)
    out = ops.paged_attention(q, k, v, pos, table, q_pos)
    assert np.asarray(out)[1].max() == 0.0 and np.asarray(out)[1].min() == 0.0
    exp = attention(q, k, v, q_pos, pos, table=table, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(out)[0], np.asarray(exp)[0],
                               atol=2e-5, rtol=2e-5)


def test_paged_kernel_fully_unallocated_table_column_is_neutral():
    """A -1 table column must contribute exactly-zero probability mass:
    inserting one changes nothing."""
    q, k, v, pos, table, q_pos, _, _ = _pool_case(
        1, 4, 2, 32, 8, 4, jnp.float32, used_cols=4, seed=7)
    out_full = ops.paged_attention(q, k, v, pos, table, q_pos)
    # same pages + two extra -1 columns interleaved at the end
    wide = jnp.concatenate(
        [table, jnp.full((1, 2), -1, jnp.int32)], axis=1)
    out_wide = ops.paged_attention(q, k, v, pos, wide, q_pos)
    np.testing.assert_array_equal(np.asarray(out_full), np.asarray(out_wide))


def test_swa_ring_column_windowing_matches_scan():
    """SWA hands the kernel only the ring columns; positions wrap the
    ring across page boundaries and the window mask must stay exact."""
    window, page, n_cols = 20, 8, 8
    nb = swa_ring_blocks(window, page, n_cols)          # 3 ring pages
    B, Hq, Hkv, D = 1, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    N = 8
    k = jax.random.normal(ks[0], (N, page, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[1], (N, page, Hkv, D), jnp.float32)
    ring = nb * page
    # a long sequence wrapped into the ring: position p lives at
    # (p % ring) — fill pages so every ring slot holds its LATEST owner
    q_pos_val = 45
    pos = np.full((N, page), -1, np.int32)
    table = np.asarray([[2, 5, 1] + [-1] * (n_cols - 3)], np.int32)
    for p in range(q_pos_val + 1):
        sl = p % ring
        pos[table[0, sl // page], sl % page] = p
    q = jax.random.normal(ks[2], (B, 1, Hq, D), jnp.float32)
    q_pos = jnp.asarray([[q_pos_val]], jnp.int32)
    tab = jnp.asarray(table)[:, :nb]
    out = ops.paged_attention(q, k, v, jnp.asarray(pos), tab, q_pos,
                              window=window)
    exp = attention(q, k, v, q_pos, jnp.asarray(pos), window=window,
                    table=tab, kv_chunk=page)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# Engine-level parity under use_kernel=True
# ---------------------------------------------------------------------------

def _tiny_cfg(n_kv=2):
    cfg = get_smoke_config("gpt3-24l")
    return dataclasses.replace(cfg, vocab_size=128, d_model=128, d_ff=256,
                               n_heads=4, n_kv_heads=n_kv, head_dim=32)


def _run(params, cfg, prompts, *, paged, use_kernel, max_new=4, **kw):
    eng = ServingEngine(params, cfg, paged=paged, use_kernel=use_kernel,
                        **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new=max_new))
    return {r.req_id: r.generated for r in eng.run()}


@pytest.mark.parametrize("arch", ["gpt3-24l", "gemma3-12b",
                                  "deepseek-v3-671b"])
def test_kernel_engine_matches_scan_engine_and_generate(arch):
    """KV-GQA / SWA / MLA configs: the kernel-path engine must emit the
    same tokens as the scan-path paged engine, the dense engine, and
    generate().  Mixed prompt lengths straddle page and chunk
    boundaries; 2 slots over more requests exercise slot recycling."""
    if arch == "gpt3-24l":
        cfg = _tiny_cfg(n_kv=2)            # GQA ratio 2 through the engine
        prompts = [[7], [1, 2, 3], list(range(5, 22)),
                   [9, 8, 7, 6, 5, 4, 3, 2, 1]]
        kw = dict(slots=2, cache_len=64, chunk=4, page_size=16)
    elif arch == "gemma3-12b":             # SWA window 64 + softcap
        cfg = get_smoke_config(arch)
        prompts = [[(i * 7 + 3) % cfg.vocab_size for i in range(80)], [5, 6]]
        kw = dict(slots=2, cache_len=128, chunk=16, page_size=16)
    else:                                  # MLA latent pool (MoE caveat:
        cfg = get_smoke_config(arch)       # whole-prompt admits)
        prompts = [[5, 6, 7, 8, 9]]
        kw = dict(slots=1, cache_len=64, chunk=16, page_size=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    scan = _run(params, cfg, prompts, paged=True, use_kernel=False, **kw)
    kern = _run(params, cfg, prompts, paged=True, use_kernel=True, **kw)
    dense = _run(params, cfg, prompts, paged=False, use_kernel=False, **kw)
    refs = [generate(params, cfg, jnp.asarray([p], jnp.int32),
                     max_new=4)[0, len(p):].tolist() for p in prompts]
    for i in range(len(prompts)):
        assert kern[i] == scan[i] == dense[i] == refs[i], (
            arch, i, kern[i], scan[i], dense[i], refs[i])


def test_kernel_engine_hybrid_ssm_state_coexists():
    """Jamba: paged KV pools walked by the kernel coexist with per-slot
    recurrent state (which ignores use_kernel)."""
    cfg = get_smoke_config("jamba-1.5-large-398b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[1, 2, 3], [5, 6, 7, 8, 9]]
    kw = dict(slots=2, cache_len=64, chunk=64, page_size=16)
    scan = _run(params, cfg, prompts, paged=True, use_kernel=False, **kw)
    kern = _run(params, cfg, prompts, paged=True, use_kernel=True, **kw)
    assert kern == scan


def test_kernel_engine_recycled_blocks_scrubbed():
    """slots=1, pool exactly one request wide: request 2 decodes through
    the kernel on request 1's recycled blocks — scrubbing must hold
    under the kernel read path too."""
    cfg = _tiny_cfg(n_kv=4)
    params = init_params(jax.random.PRNGKey(2), cfg)
    eng = ServingEngine(params, cfg, slots=1, cache_len=32, chunk=4,
                        paged=True, page_size=8, num_blocks=2,
                        use_kernel=True)
    eng.submit(Request(0, [5, 6, 7, 8, 9, 10, 11], max_new=4))
    eng.submit(Request(1, [1, 2, 3], max_new=4))
    done = {r.req_id: r.generated for r in eng.run()}
    for rid, p in [(0, [5, 6, 7, 8, 9, 10, 11]), (1, [1, 2, 3])]:
        ref = generate(params, cfg, jnp.asarray([p], jnp.int32),
                       max_new=4)[0, len(p):].tolist()
        assert done[rid] == ref, (rid, done[rid], ref)


def test_use_kernel_requires_paged():
    """Dense rings have no block table to walk — asking for the kernel
    without paging must fail loudly, not silently serve the scan path."""
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="use_kernel"):
        ServingEngine(params, cfg, paged=False, use_kernel=True)


def test_kernel_engine_sampled_and_greedy_slots():
    """Kernel path + in-jit sampling: the greedy slot stays bitwise equal
    to the all-greedy scan engine while a top-k/penalty slot samples."""
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ref = _run(params, cfg, [[1, 2, 3]], paged=True, use_kernel=False,
               max_new=6, slots=2, cache_len=64, chunk=4, page_size=16)
    eng = ServingEngine(params, cfg, slots=2, cache_len=64, chunk=4,
                        paged=True, page_size=16, use_kernel=True)
    eng.submit(Request(0, [1, 2, 3], max_new=6))
    eng.submit(Request(1, [4, 5, 6], max_new=6, temperature=1.0,
                       top_p=0.9, top_k=8, rep_penalty=1.3))
    done = {r.req_id: r.generated for r in eng.run()}
    assert done[0] == ref[0], (done[0], ref[0])
    assert all(0 <= t < cfg.vocab_size for t in done[1])
