"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single
real CPU device; only the dry-run subprocess uses 512 placeholder devices.
"""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
