"""repro.analysis — rule true/false positives, suppression, baseline
lifecycle, the PAL002 dynamic cost-plan cross-check, and the CLI.

Fixture sources are analyzed in-memory via ``analyze_source`` with a
fake repo-relative path (path scoping is part of the contract: DET001
and HOT001 only fire in replay-/host-critical trees).
"""
import json
import re
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import (analyze_source, apply_baseline, load_baseline,
                            repo_root, run_analysis, write_baseline)
from repro.analysis.baseline import BASELINE_NAME

SERVE = "src/repro/serve/mod.py"
KERN = "src/repro/kernels/mod.py"


def lint(src, rel=SERVE, only=None, config=None):
    return analyze_source(src, rel, only=only, config=config)


def rules_of(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# DET001 — unseeded nondeterminism
# ---------------------------------------------------------------------------

DET_TP = """
import random
import time
import numpy as np

def pick(xs):
    if np.random.rand() > 0.5:          # hidden global numpy state
        return random.choice(xs)        # hidden global stdlib state
    return time.time()                  # wall clock
"""


def test_det001_flags_unseeded_and_clocks():
    found = lint(DET_TP, only=["DET001"])
    assert len(found) == 3
    assert all(f.rule == "DET001" for f in found)
    assert found[0].symbol == "pick"


def test_det001_allows_seeded_and_jax_random():
    src = """
import random
import numpy as np
import jax

def pick(xs, key):
    rng = np.random.default_rng(0)
    st = np.random.RandomState(1234)
    r = random.Random(7)
    k = jax.random.split(key)
    return rng.integers(3), st.rand(), r.random(), k
"""
    assert lint(src, only=["DET001"]) == []


def test_det001_scoped_to_replay_critical_trees():
    assert lint(DET_TP, rel="src/repro/train/mod.py", only=["DET001"]) == []
    assert lint(DET_TP, rel="tests/test_mod.py", only=["DET001"]) == []
    assert lint(DET_TP, rel="src/repro/core/mod.py", only=["DET001"]) != []


def test_det001_ignores_local_names_shadowing_modules():
    src = """
def draw(random):
    return random.random()              # parameter, not the module
"""
    assert lint(src, only=["DET001"]) == []


# ---------------------------------------------------------------------------
# JIT001 — donated buffer read before rebinding
# ---------------------------------------------------------------------------

def test_jit001_direct_kwarg_read_after_donation():
    src = """
import jax

class Engine:
    def setup(self, f):
        self._step = jax.jit(f, donate_argnums=(1,))

    def tick(self):
        logits = self._step(self.params, self.caches)
        return logits, self.caches      # caches donated, never rebound
"""
    found = lint(src, only=["JIT001"])
    assert len(found) == 1
    assert "self.caches" in found[0].message


def test_jit001_conditional_dn_dict_counts_as_donating():
    src = """
import jax

class Engine:
    def setup(self, f, donate):
        dn = dict(donate_argnums=(1, 2)) if donate else {}
        self._step = jax.jit(f, **dn)

    def tick(self):
        logits = self._step(self.params, self.caches, self.seen)
        x = self.seen.sum()             # donated at position 2
        return logits, x
"""
    found = lint(src, only=["JIT001"])
    assert len(found) == 1
    assert "self.seen" in found[0].message


def test_jit001_same_statement_rebind_is_clean():
    src = """
import jax

class Engine:
    def setup(self, f):
        self._step = jax.jit(f, donate_argnums=(1,))

    def tick(self):
        logits, self.caches = self._step(self.params, self.caches)
        return logits, self.caches      # rebound: alive again
"""
    assert lint(src, only=["JIT001"]) == []


def test_jit001_loop_carried_donation():
    src = """
import jax

class Engine:
    def setup(self, f):
        self._step = jax.jit(f, donate_argnums=(1,))

    def run(self, n):
        for _ in range(n):
            tokens = self.caches.tokens    # stale on iteration 2+
            _ = self._step(self.params, self.caches)
"""
    found = lint(src, only=["JIT001"])
    # both the attribute read AND the re-donation of the dead buffer
    # into the next call are loop-carried hazards
    assert len(found) == 2
    assert all("self.caches" in f.message for f in found)
    assert {f.line for f in found} == {10, 11}


def test_jit001_branch_donation_unions():
    src = """
import jax

class Engine:
    def setup(self, f):
        self._step = jax.jit(f, donate_argnums=(1,))

    def tick(self, fast):
        if fast:
            out = self._step(self.params, self.caches)
        else:
            out = None
        return self.caches              # dead on the fast path
"""
    assert len(lint(src, only=["JIT001"])) == 1


def test_jit001_partial_decorator():
    src = """
import jax
from functools import partial

@partial(jax.jit, donate_argnums=(0,))
def step(caches, tokens):
    return caches

def drive(caches, tokens):
    out = step(caches, tokens)
    return caches.mean()                # donated into step()
"""
    assert len(lint(src, only=["JIT001"])) == 1


# ---------------------------------------------------------------------------
# HOT001 — per-element dispatch in host loops
# ---------------------------------------------------------------------------

def test_hot001_jnp_and_at_update_in_loop():
    src = """
import jax.numpy as jnp

def admit(reqs, table):
    for i, r in enumerate(reqs):
        x = jnp.asarray(r.tokens)       # one dispatch per request
        table = table.at[i].set(x)      # one full copy per request
    return table
"""
    found = lint(src, only=["HOT001"])
    assert len(found) == 2
    assert all(f.rule == "HOT001" for f in found)


def test_hot001_batched_outside_loop_is_clean():
    src = """
import numpy as np
import jax.numpy as jnp

def admit(reqs):
    buf = np.zeros((len(reqs), 8), np.int32)
    for i, r in enumerate(reqs):
        buf[i] = r.tokens               # numpy in the loop: fine
    return jnp.asarray(buf)             # one conversion per tick
"""
    assert lint(src, only=["HOT001"]) == []


def test_hot001_only_in_serve_tree():
    src = """
import jax.numpy as jnp

def body(xs):
    for x in xs:                        # traced/unrolled code: fine
        xs = jnp.sin(xs)
    return xs
"""
    assert lint(src, rel="src/repro/models/mod.py", only=["HOT001"]) == []
    assert lint(src, rel=SERVE, only=["HOT001"]) != []


# ---------------------------------------------------------------------------
# ALLOC001 — free() return ignored
# ---------------------------------------------------------------------------

ALLOC_SRC = """
from repro.serve.engine import BlockAllocator

def release(a, blocks):
    a.free(blocks){suffix}
"""


def test_alloc001_bare_free_statement():
    found = lint(ALLOC_SRC.format(suffix=""), only=["ALLOC001"])
    assert len(found) == 1
    assert "physically-freed" in found[0].message


def test_alloc001_consumed_return_is_clean():
    src = """
from repro.serve.engine import BlockAllocator

def release(a, blocks, pool):
    for b in a.free(blocks):
        pool[b] = 0
"""
    assert lint(src, only=["ALLOC001"]) == []


def test_alloc001_requires_block_allocator_in_module():
    src = """
def close(handle):
    handle.free()                       # unrelated free() API
"""
    assert lint(src, only=["ALLOC001"]) == []


# ---------------------------------------------------------------------------
# PAL001 — grid/BlockSpec consistency
# ---------------------------------------------------------------------------

def test_pal001_index_map_arity_mismatch():
    src = """
import jax.experimental.pallas as pl

def run(x, kernel):
    return pl.pallas_call(
        kernel,
        grid=(4, 4),
        in_specs=[pl.BlockSpec((1, 8), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 8), lambda i, j: (i, 0)),
    )(x)
"""
    found = lint(src, rel=KERN, only=["PAL001"])
    assert len(found) == 1
    assert "takes 1 arg(s)" in found[0].message


def test_pal001_scalar_prefetch_extends_arity():
    src = """
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

def run(x, kernel):
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(4, 4),
        in_specs=[pl.BlockSpec((1, 8), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((1, 8), lambda i, j, tab, qp: (i, 0)),
    )
    return pl.pallas_call(kernel, grid_spec=grid_spec)(x)
"""
    found = lint(src, rel=KERN, only=["PAL001"])
    # in_spec lambda has 2 args but grid rank 2 + 2 prefetch refs = 4
    assert len(found) == 1
    assert "2 scalar-prefetch" in found[0].message


def test_pal001_block_rank_vs_index_coords():
    src = """
import jax.experimental.pallas as pl

def run(x, kernel):
    return pl.pallas_call(
        kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((1, 8, 16), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 8, 16), lambda i: (i, 0, 0)),
    )(x)
"""
    found = lint(src, rel=KERN, only=["PAL001"])
    assert len(found) == 1
    assert "returns 2 coordinate(s)" in found[0].message


def test_pal001_named_local_index_fn_resolved():
    src = """
import jax.experimental.pallas as pl

def run(x, kernel, Hq):
    def kv_index(bh, iq):               # arity 2 vs grid rank 3
        return (bh // Hq, iq, 0)
    return pl.pallas_call(
        kernel,
        grid=(2, 4, 4),
        in_specs=[pl.BlockSpec((1, 8, 8), kv_index)],
        out_specs=pl.BlockSpec((1, 8, 8), lambda b, i, k: (b, i, 0)),
    )(x)
"""
    found = lint(src, rel=KERN, only=["PAL001"])
    assert len(found) == 1 and "takes 2 arg(s)" in found[0].message


def test_pal001_vmem_budget():
    src = """
import jax.experimental.pallas as pl

def run(x, kernel):
    return pl.pallas_call(
        kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((4096, 4096), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((4096, 4096), lambda i: (i, 0)),
    )(x)
"""
    found = lint(src, rel=KERN, only=["PAL001"])
    assert len(found) == 1 and "VMEM budget" in found[0].message
    # raising the budget clears it without touching the code
    assert lint(src, rel=KERN, only=["PAL001"],
                config={"vmem_budget": 256 * 1024 * 1024}) == []


def test_pal001_consistent_site_is_clean():
    src = """
import jax.experimental.pallas as pl

def run(x, kernel, n):
    return pl.pallas_call(
        kernel,
        grid=(n, 4),
        in_specs=[pl.BlockSpec((1, 128), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 128), lambda i, j: (i, j)),
    )(x)
"""
    assert lint(src, rel=KERN, only=["PAL001"]) == []


def test_pal001_dynamic_specs_are_skipped():
    # specs built elsewhere and passed through a name: not statically
    # visible, must not false-positive
    src = """
import jax.experimental.pallas as pl

def run(x, kernel, specs, out_spec):
    return pl.pallas_call(
        kernel, grid=(4, 4), in_specs=specs, out_specs=out_spec)(x)
"""
    assert lint(src, rel=KERN, only=["PAL001"]) == []


# ---------------------------------------------------------------------------
# PAL002 — cost_estimate provenance (static half)
# ---------------------------------------------------------------------------

PAL2_TP = """
import jax.experimental.pallas as pl

def plan(n):
    specs = [pl.BlockSpec((1, 8), lambda i, j: (i, j))]
    return specs, pl.BlockSpec((1, 8), lambda i, j: (i, j)), n * 64

def run(x, kernel, n):
    in_specs, out_spec, _ = plan(n)
    cost = pl.CostEstimate(flops=1, transcendentals=0, bytes_accessed=999)
    return pl.pallas_call(
        kernel, grid=(n, 4), in_specs=in_specs, out_specs=out_spec,
        cost_estimate=cost)(x)
"""


def test_pal002_literal_cost_next_to_plan_specs():
    found = lint(PAL2_TP, rel=KERN, only=["PAL002"])
    assert len(found) == 1
    assert "`plan(...)`" in found[0].message


def test_pal002_cost_derived_from_plan_is_clean():
    src = PAL2_TP.replace(
        "cost = pl.CostEstimate(flops=1, transcendentals=0, "
        "bytes_accessed=999)",
        "cost = make_cost(n)") + """

def make_cost(n):
    _, _, byt = plan(n)
    return pl.CostEstimate(flops=1, transcendentals=0, bytes_accessed=byt)
"""
    assert lint(src, rel=KERN, only=["PAL002"]) == []


def test_pal002_real_kernel_clean_and_drift_caught():
    """The shipped paged_attention derives its cost from _spec_plan; a
    literal cost spliced into the same source must trip PAL002."""
    path = repo_root() / "src/repro/kernels/paged_attention.py"
    src = path.read_text()
    rel = "src/repro/kernels/paged_attention.py"
    assert lint(src, rel=rel, only=["PAL002"]) == []

    munged = re.sub(
        r"cost = paged_attention_cost\(.*?interpret=interpret\)",
        "cost = pl.CostEstimate(flops=1, transcendentals=0, "
        "bytes_accessed=12345)",
        src, count=1, flags=re.S)
    assert munged != src, "fixture out of date: cost call not found"
    assert rules_of(lint(munged, rel=rel, only=["PAL002"])) == ["PAL002"]


# ---------------------------------------------------------------------------
# PAL002 — dynamic cross-check: simulate the DMA schedule the grid
# actually executes and compare against the advertised CostEstimate
# ---------------------------------------------------------------------------

def test_paged_attention_cost_matches_simulated_dma_schedule(monkeypatch):
    """Walk the real grid over the specs actually handed to pallas_call
    (sequential page axis innermost), count a fetch whenever a spec's
    index_map output changes between consecutive steps, and require the
    summed bytes to equal paged_attention_cost's bytes_accessed."""
    import jax.numpy as jnp

    from repro.kernels import paged_attention as pa

    B, Hq, Hkv, page, n_cols, D = 2, 4, 2, 8, 3, 16
    N = B * n_cols                       # fully allocated, all distinct
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, 1, Hq, D), jnp.float32)
    k = jnp.asarray(rng.randn(N, page, Hkv, D), jnp.float32)
    v = jnp.asarray(rng.randn(N, page, Hkv, D), jnp.float32)
    table = jnp.arange(N, dtype=jnp.int32).reshape(B, n_cols)
    pos = jnp.broadcast_to(
        jnp.arange(page, dtype=jnp.int32)[None], (N, page))
    pos = (pos + jnp.arange(N, dtype=jnp.int32)[:, None] * page) % (
        page * n_cols)
    q_pos = jnp.full((B, 1), page * n_cols - 1, jnp.int32)

    captured = {}
    real_call = pa.pl.pallas_call

    def spy(kernel, **kw):
        captured.update(kw)
        inner = real_call(kernel, **kw)

        def runner(*operands):
            captured["operands"] = operands
            return inner(*operands)
        return runner

    monkeypatch.setattr(pa.pl, "pallas_call", spy)
    pa.paged_attention_fwd(q, k, v, pos, table, q_pos, interpret=True)

    gs = captured["grid_spec"]
    cost = captured["cost_estimate"]
    nsp = gs.num_scalar_prefetch
    prefetch = captured["operands"][:nsp]
    arrays = captured["operands"][nsp:]
    out_specs = gs.out_specs
    if not isinstance(out_specs, (list, tuple)):
        out_specs = [out_specs]
    out_isz = np.dtype(captured["out_shape"].dtype).itemsize

    # scalar-prefetch operands live in SMEM and are read once, whole
    simulated = sum(int(np.asarray(p).size) * np.dtype(p.dtype).itemsize
                    for p in prefetch)
    plan = [(s, np.dtype(a.dtype).itemsize)
            for s, a in zip(gs.in_specs, arrays)]
    plan += [(s, out_isz) for s in out_specs]
    assert len(gs.in_specs) == len(arrays)

    g0, g1 = gs.grid                     # (parallel, sequential-pages)
    for spec, isz in plan:
        fetches, prev = 0, None
        for bh in range(g0):
            for ic in range(g1):
                idx = tuple(int(x) for x in spec.index_map(
                    bh, ic, *prefetch))
                if idx != prev:
                    fetches += 1
                    prev = idx
        blk = int(np.prod(spec.block_shape))
        simulated += fetches * blk * isz

    assert simulated == cost.bytes_accessed, (
        "advertised DMA bytes drifted from the BlockSpec plan: "
        f"simulated {simulated} vs CostEstimate {cost.bytes_accessed}")


# ---------------------------------------------------------------------------
# suppression + baseline machinery
# ---------------------------------------------------------------------------

def test_inline_suppression_by_rule_and_all():
    base = """
import time

def stamp():
    return time.time(){comment}
"""
    assert len(lint(base.format(comment=""), only=["DET001"])) == 1
    assert lint(base.format(
        comment="  # repro-lint: disable=DET001"), only=["DET001"]) == []
    assert lint(base.format(
        comment="  # repro-lint: disable=all"), only=["DET001"]) == []
    # unrelated rule name does not suppress
    assert len(lint(base.format(
        comment="  # repro-lint: disable=HOT001"), only=["DET001"])) == 1


def test_baseline_grandfathers_by_key_and_count(tmp_path):
    found = lint(DET_TP, only=["DET001"])
    assert len(found) == 3
    bl_path = tmp_path / BASELINE_NAME
    bl = write_baseline(bl_path, found)
    assert len(bl.entries) == 1 and bl.entries[0].count == 3
    assert bl.entries[0].justification.startswith("TODO")

    # same findings: all grandfathered, nothing stale
    new, old, stale = apply_baseline(found, load_baseline(bl_path))
    assert (len(new), len(old), len(stale)) == (0, 3, 0)

    # a FOURTH violation at the same key is new, not grandfathered
    extra = lint(DET_TP + "\n\ndef more():\n    return time.time()\n",
                 only=["DET001"])
    new, old, stale = apply_baseline(extra, load_baseline(bl_path))
    assert (len(new), len(old)) == (1, 3)


def test_baseline_stale_entries_expire(tmp_path):
    found = lint(DET_TP, only=["DET001"])
    bl_path = tmp_path / BASELINE_NAME
    write_baseline(bl_path, found)

    # violations fixed -> every entry is stale; rewrite drops them but
    # keeps the justification of entries that still match
    new, old, stale = apply_baseline([], load_baseline(bl_path))
    assert len(stale) == 1
    rewritten = write_baseline(bl_path, [], load_baseline(bl_path))
    assert rewritten.entries == []


def test_missing_baseline_is_empty():
    assert load_baseline(Path("/nonexistent/baseline.json")).entries == []


# ---------------------------------------------------------------------------
# e2e: the shipped tree is clean under the checked-in baseline
# ---------------------------------------------------------------------------

def test_repo_clean_under_checked_in_baseline():
    root = repo_root()
    report = run_analysis(root)
    assert report.parse_errors == []
    assert report.files_scanned > 50
    baseline = load_baseline(root / BASELINE_NAME)
    new, _, stale = apply_baseline(report.findings, baseline)
    assert new == [], "\n".join(f.format() for f in new)
    assert stale == [], [e.key for e in stale]
    # the baseline file itself carries real justifications
    assert all(not e.justification.startswith("TODO")
               for e in baseline.entries)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _cli(*args, cwd=None):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=cwd or str(repo_root()),
        env={"PYTHONPATH": str(repo_root() / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp"})


def test_cli_strict_exits_zero_on_shipped_tree():
    proc = _cli("--strict")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 new finding(s)" in proc.stdout


def test_cli_unknown_rule_is_usage_error():
    proc = _cli("--only", "NOPE999")
    assert proc.returncode == 2
    assert "unknown rule" in proc.stderr


def _fixture_root(tmp_path):
    pkg = tmp_path / "src" / "repro" / "serve"
    pkg.mkdir(parents=True)
    (pkg / "bad.py").write_text(
        "import time\n\n\ndef stamp():\n    return time.time()\n")
    return tmp_path


def test_cli_json_report_and_only_filter(tmp_path):
    root = _fixture_root(tmp_path)
    out = tmp_path / "report.json"
    proc = _cli("--root", str(root), "--only", "DET001,HOT001",
                "--format", "json", "--output", str(out))
    assert proc.returncode == 1          # one new finding
    blob = json.loads(out.read_text())
    assert blob["summary"]["new"] == 1
    assert blob["summary"]["by_rule"] == {"DET001": 1}
    assert blob["findings"][0]["status"] == "new"
    assert "DET001" in blob["rules"] and "HOT001" in blob["rules"]


def test_cli_baseline_write_then_strict_then_expiry(tmp_path):
    root = _fixture_root(tmp_path)
    bad = root / "src" / "repro" / "serve" / "bad.py"

    assert _cli("--root", str(root)).returncode == 1
    proc = _cli("--root", str(root), "--write-baseline")
    assert proc.returncode == 0 and "1 baseline entry" in proc.stdout
    # grandfathered now (TODO justification is a review-time concern)
    assert _cli("--root", str(root), "--strict").returncode == 0

    # fix the violation: non-strict passes, strict refuses stale entries
    bad.write_text("def stamp(tick):\n    return tick\n")
    assert _cli("--root", str(root)).returncode == 0
    proc = _cli("--root", str(root), "--strict")
    assert proc.returncode == 1
    assert "stale" in proc.stdout


def test_cli_list_rules_names_all_shipped_rules():
    proc = _cli("--list-rules")
    assert proc.returncode == 0
    for rid in ("DET001", "JIT001", "PAL001", "PAL002", "HOT001",
                "ALLOC001"):
        assert rid in proc.stdout
