"""Per-architecture smoke tests: each assigned arch instantiates a REDUCED
same-family variant (<=2 periods, d_model<=512, <=4 experts) and runs one
forward + one train step on CPU, asserting output shapes and no NaNs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_smoke_config
from repro.models.transformer import forward, init_cache, init_params
from repro.optim.adamw import adamw
from repro.train.step import make_train_step


def _batch_for(cfg, key, B=2, S=16):
    if cfg.ext_embed_dim:
        return {"embeds": jax.random.normal(key, (B, S, cfg.ext_embed_dim)),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nan(arch, rng):
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 512 and cfg.n_experts <= 4
    params = init_params(rng, cfg)
    B, S = 2, 16
    batch = _batch_for(cfg, rng, B, S)
    logits, aux, _ = forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not jnp.isnan(logits).any()
    assert not jnp.isnan(aux)


# known seed failures (ROADMAP "Known seed failures"): the MoE train step
# dies in backward — jax has no differentiation rule for the
# optimization_barrier marking the EP dispatch boundary in moe_apply
_MOE_TRAIN_XFAIL = pytest.mark.xfail(
    strict=False,
    reason="known seed failure: MoE train step — no differentiation rule "
           "for optimization_barrier in the EP dispatch (ROADMAP 'Known "
           "seed failures'); inference/serving unaffected")
_MOE_ARCHS = ("deepseek-v3-671b", "jamba-1.5-large-398b",
              "qwen3-moe-235b-a22b")


@pytest.mark.parametrize(
    "arch", [pytest.param(a, marks=_MOE_TRAIN_XFAIL) if a in _MOE_ARCHS
             else a for a in ASSIGNED_ARCHS])
def test_one_train_step(arch, rng):
    cfg = get_smoke_config(arch)
    params = init_params(rng, cfg)
    opt = adamw(1e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, remat=True))
    batch = _batch_for(cfg, rng, 2, 16)
    new_params, state, metrics = step(params, state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually changed
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                - b.astype(jnp.float32)).sum()),
                     params, new_params))
    assert moved > 0.0


@pytest.mark.parametrize("arch", ["qwen3-8b", "gemma3-12b", "rwkv6-7b",
                                  "jamba-1.5-large-398b", "deepseek-v3-671b"])
def test_decode_matches_teacher_forcing(arch, rng):
    """Prefill+decode over caches reproduces the full-sequence forward
    (MoE capacity drops disabled so the check is exact-ish)."""
    cfg = get_smoke_config(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    params = init_params(rng, cfg)
    B, S_total, S_prompt = 2, 24, 16
    toks = jax.random.randint(rng, (B, S_total), 0, cfg.vocab_size)
    ref, _, _ = forward(params, cfg, {"tokens": toks})
    caches = init_cache(cfg, B, S_total)
    pos = jnp.broadcast_to(jnp.arange(S_prompt, dtype=jnp.int32)[None],
                           (B, S_prompt))
    lp, _, caches = forward(params, cfg, {"tokens": toks[:, :S_prompt]},
                            caches=caches, positions=pos)
    assert jnp.abs(lp - ref[:, :S_prompt]).max() < 0.05
    errs = []
    for t in range(S_prompt, S_total):
        posd = jnp.full((B, 1), t, jnp.int32)
        ld, _, caches = forward(params, cfg, {"tokens": toks[:, t:t + 1]},
                                caches=caches, positions=posd, decode=True)
        errs.append(float(jnp.abs(ld[:, 0] - ref[:, t]).max()))
    import numpy as np
    # MoE routers amplify bf16 noise on near-tie top-k picks: bound the
    # typical step tightly and allow rare tie-flips a loose cap.
    assert np.median(errs) < 0.12, errs
    assert max(errs) < (1.5 if cfg.n_experts else 0.12), errs
