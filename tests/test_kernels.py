"""Per-kernel correctness sweeps: shapes × dtypes against the pure-jnp
oracles in ``repro.kernels.ref`` (kernels execute in interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,S,D,window",
    [(2, 4, 2, 64, 64, 0),      # GQA causal
     (1, 8, 8, 128, 128, 0),    # MHA, MXU-aligned
     (2, 4, 1, 37, 80, 16),     # odd sizes + window (padding paths)
     (1, 2, 2, 192, 64, 64),    # sliding window
     (1, 16, 4, 48, 256, 0)])   # wide heads (gemma3-style)
def test_flash_attention_vs_ref(B, Hq, Hkv, S, D, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
    out = ops.flash_attention(q, k, v, window=window)
    exp = ref.attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("n", [17, 256, 1000, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int8_quant_roundtrip(n, dtype):
    x = (jax.random.normal(KEY, (n,), jnp.float32) * 5).astype(dtype)
    q, s = ops.int8_quantize(x)
    qr, sr = ref.int8_quantize_ref(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    xd = ops.int8_dequantize(q, s, (n,))
    # absmax int8: per-block error <= absmax/127 (half-step rounding)
    err = np.abs(np.asarray(xd) - np.asarray(x, np.float32))
    assert err.max() <= float(jnp.abs(x).max()) / 127.0 + 1e-6


@pytest.mark.parametrize("B,S,di,ds,chunk,dib",
                         [(1, 32, 64, 8, 16, 64),
                          (2, 96, 192, 16, 32, 64),
                          (1, 50, 48, 4, 64, 128)])  # non-divisible pads
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_scan_vs_ref(B, S, di, ds, chunk, dib, dtype):
    ks = jax.random.split(KEY, 5)
    x = (jax.random.normal(ks[0], (B, S, di), jnp.float32) * 0.5).astype(dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (B, S, di))) * 0.1).astype(dtype)
    b = jax.random.normal(ks[2], (B, S, ds), dtype)
    c = jax.random.normal(ks[3], (B, S, ds), dtype)
    a = -jnp.exp(jax.random.normal(ks[4], (di, ds), jnp.float32))
    out = ops.mamba_scan(x, dt, b, c, a, chunk=chunk, di_block=dib)
    exp = ref.mamba_scan_ref(x, dt, b, c, a)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=5 * _tol(dtype), rtol=5 * _tol(dtype))


@pytest.mark.parametrize("B,S,H,hd,chunk", [(1, 32, 2, 16, 8),
                                            (2, 64, 3, 16, 16),
                                            (1, 40, 1, 32, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv_scan_vs_ref(B, S, H, hd, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    r, k, v = [jax.random.normal(kk, (B, S, H, hd), dtype)
               for kk in ks[:3]]
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, hd))).astype(dtype)
    u = (jax.random.normal(ks[4], (H, hd), jnp.float32) * 0.1)
    out = ops.rwkv_scan(r, k, v, w, u, chunk=chunk)
    exp = ref.rwkv_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=10 * _tol(dtype), rtol=10 * _tol(dtype))


def test_model_attention_matches_kernel():
    """The model's blocked jnp attention and the Pallas kernel agree (the
    model path is the production fallback on non-TPU hosts)."""
    from repro.models.layers import attention as model_attention
    B, Hq, Hkv, S, D = 2, 4, 2, 64, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    out_model = model_attention(q, k, v, pos, pos, kv_chunk=32)
    out_kernel = ops.flash_attention(q.transpose(0, 2, 1, 3),
                                     k.transpose(0, 2, 1, 3),
                                     v.transpose(0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(out_model),
                               np.asarray(out_kernel.transpose(0, 2, 1, 3)),
                               atol=2e-5, rtol=2e-5)
