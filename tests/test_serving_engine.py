"""Chunked-prefill continuous-batching engine tests: greedy parity with
``generate()`` as the correctness oracle (mixed prompt lengths, slot
reuse, SSM + SWA cache kinds), slot-recycle hygiene for every cache kind,
admission call-count bound (ceil(S/chunk) jitted steps), and input
validation."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.transformer import init_cache, init_params
from repro.serve.engine import (Request, ServingEngine, _clear_slot, generate)


def _tiny_cfg():
    cfg = get_smoke_config("gpt3-24l")
    return dataclasses.replace(cfg, vocab_size=128, d_model=128, d_ff=256,
                               n_heads=4, n_kv_heads=4, head_dim=32)


# ---------------------------------------------------------------------------
# Greedy parity vs generate() — mixed prompt lengths, slot reuse, chunking
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["gpt3-24l", "gemma3-12b", "rwkv6-7b"])
def test_chunked_engine_matches_generate(arch):
    """Prompt lengths straddle the chunk size (1, <chunk, crossing one
    boundary, crossing two with a remainder); 4 requests over 2 slots
    forces slot reuse.  Covers full-attention KV, SWA ring and RWKV
    state caches."""
    cfg = _tiny_cfg() if arch == "gpt3-24l" else get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, slots=2, cache_len=64, chunk=4)
    prompts = [[7], [1, 2, 3], [5, 6, 7, 8, 9], [9, 8, 7, 6, 5, 4, 3, 2, 1]]
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new=4))
    done = {r.req_id: r.generated for r in eng.run()}
    assert sorted(done) == [0, 1, 2, 3]
    for i, p in enumerate(prompts):
        ref = generate(params, cfg, jnp.asarray([p], jnp.int32),
                       max_new=4)[0, len(p):].tolist()
        assert done[i] == ref, (arch, i, done[i], ref)


def test_late_arrival_joins_running_batch():
    """A request submitted mid-decode is admitted by chunked prefill into
    a shared cache that already holds other requests' live KV."""
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(1), cfg)
    eng = ServingEngine(params, cfg, slots=2, cache_len=64, chunk=4)
    eng.submit(Request(0, [1, 2, 3, 4, 5, 6], max_new=8))
    ticks = 0
    while eng.tick():
        ticks += 1
        if ticks == 2:
            eng.submit(Request(1, [9, 8, 7, 6, 5], max_new=4))
    done = {r.req_id: r.generated for r in eng.finished}
    for rid, p in [(0, [1, 2, 3, 4, 5, 6]), (1, [9, 8, 7, 6, 5])]:
        ref = generate(params, cfg, jnp.asarray([p], jnp.int32),
                       max_new=len(done[rid]))[0, len(p):].tolist()
        assert done[rid] == ref, (rid, done[rid], ref)


def test_mamba_hybrid_chunked_parity():
    """Jamba (Mamba + attention + MoE hybrid): chunked admission with an
    idle masked slot must reproduce generate() — covers the conv-history
    and SSM-state carry across chunk boundaries and the row-wise state
    restore for masked slots."""
    cfg = get_smoke_config("jamba-1.5-large-398b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, slots=2, cache_len=64, chunk=4)
    prompts = [[1, 2, 3], [5, 6, 7, 8, 9], [9, 8, 7, 6, 5, 4, 3]]
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new=4))
    done = {r.req_id: r.generated for r in eng.run()}
    for i, p in enumerate(prompts):
        ref = generate(params, cfg, jnp.asarray([p], jnp.int32),
                       max_new=4)[0, len(p):].tolist()
        assert done[i] == ref, (i, done[i], ref)


def test_mla_latent_cache_parity():
    """DeepSeek-V3 (MLA latent cache + MoE): engine parity vs generate()
    through the per-row masked latent ring write and the absorbed decode
    path.  MoE capacity-factor dropping depends on the per-call token
    count, so chunked prefill is NOT bitwise-equal for MoE models —
    admission here is shape-identical to generate()'s prefill (slots=1,
    chunk >= prompt), which isolates the MLA cache machinery."""
    cfg = get_smoke_config("deepseek-v3-671b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[1, 2, 3], [5, 6, 7, 8, 9], [9, 8, 7, 6, 5, 4, 3, 2, 1]]
    for p in prompts:
        eng = ServingEngine(params, cfg, slots=1, cache_len=64,
                            chunk=len(p))
        eng.submit(Request(0, p, max_new=4))
        out = eng.run()[0].generated
        ref = generate(params, cfg, jnp.asarray([p], jnp.int32),
                       max_new=4)[0, len(p):].tolist()
        assert out == ref, (p, out, ref)


# ---------------------------------------------------------------------------
# Slot recycle: no stale cache/state leaks into the next occupant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["gpt3-24l", "gemma3-12b", "rwkv6-7b"])
def test_slot_recycle_no_stale_leak(arch):
    """Second request reuses slot 0 after a LONGER first occupant: any
    surviving KV entries (valid positions past the new prompt) or carried
    recurrent state would change its greedy decode."""
    cfg = _tiny_cfg() if arch == "gpt3-24l" else get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(2), cfg)
    eng = ServingEngine(params, cfg, slots=1, cache_len=64, chunk=4)
    eng.submit(Request(0, [5, 6, 7, 8, 9, 10, 11], max_new=4))
    eng.submit(Request(1, [1, 2, 3], max_new=4))
    done = {r.req_id: r.generated for r in eng.run()}
    ref = generate(params, cfg, jnp.asarray([[1, 2, 3]], jnp.int32),
                   max_new=4)[0, 3:].tolist()
    assert done[1] == ref, (arch, done[1], ref)


@pytest.mark.parametrize("arch", ["gpt3-24l", "gemma3-12b", "rwkv6-7b",
                                  "jamba-1.5-large-398b", "deepseek-v3-671b"])
def test_clear_slot_all_cache_kinds(arch):
    """_clear_slot must zero exactly one slot's leaves for every cache
    kind (KV / MLA-latent / SSM-state / SWA-ring), set its positions to
    -1, and leave other slots untouched — including stack caches whose
    leading period axis happens to EQUAL the slot count (the seed bug
    picked the slot axis by shape comparison)."""
    cfg = get_smoke_config(arch)
    n_periods = cfg.stacks[0].n_periods if cfg.stacks else 2
    slots = max(2, n_periods)      # force the shape collision when possible
    caches = init_cache(cfg, slots, 16)
    # fill every leaf with a nonzero pattern ("pos" leaves get valid >= 0)
    def fill(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        if name == "pos":
            return jnp.zeros_like(leaf) + 3
        return jnp.ones_like(leaf)
    caches = jax.tree_util.tree_map_with_path(fill, caches)
    cleared = _clear_slot(caches, 0)

    def check(path, before, after):
        name = str(getattr(path[-1], "key", path[-1]))
        top = str(getattr(path[0], "key", path[0]))
        bdim = 1 if top == "stack" else 0
        b, a = np.asarray(before), np.asarray(after)
        if bdim:
            b = np.moveaxis(b, 0, -1).reshape(b.shape[1], -1)
            a = np.moveaxis(a, 0, -1).reshape(a.shape[1], -1)
        else:
            b, a = b.reshape(b.shape[0], -1), a.reshape(a.shape[0], -1)
        want = -1 if name == "pos" else 0
        assert (a[0] == want).all(), (arch, path, "slot 0 not cleared")
        np.testing.assert_array_equal(a[1:], b[1:],
                                      err_msg=f"{arch} {path}: other slots")
    jax.tree_util.tree_map_with_path(check, caches, cleared)


# ---------------------------------------------------------------------------
# Admission cost: ceil(S/chunk) jitted forward calls, not S
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,chunk", [(37, 8), (16, 8), (3, 8), (1, 8),
                                     (10, 1)])
def test_admission_call_count(S, chunk):
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(3), cfg)
    eng = ServingEngine(params, cfg, slots=2, cache_len=64, chunk=chunk)
    calls = []
    orig = eng._step_fn
    def counting(p, c, seen, toks, pos, *rest):
        calls.append(tuple(toks.shape))
        return orig(p, c, seen, toks, pos, *rest)
    eng._step_fn = counting
    eng.submit(Request(0, list(range(1, S + 1)), max_new=2))
    eng._admit()
    expect = math.ceil(S / chunk)
    assert len(calls) == expect, (calls, expect)
    assert eng.stats["prefill_calls"] == expect
    # every admission step is batched over all slots
    assert all(shape[0] == eng.slots for shape in calls)
    # one decode tick = exactly one more jitted call for all slots
    eng.tick()
    assert len(calls) == expect + 1 and calls[-1] == (eng.slots, 1)
    assert eng.stats["decode_calls"] == 1


def test_chunked_vs_tokenwise_same_output():
    """chunk=1 degenerates to the seed's token-level admission; any chunk
    size must produce identical greedy output."""
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(4), cfg)
    outs = []
    for chunk in (1, 3, 8, 64):
        eng = ServingEngine(params, cfg, slots=2, cache_len=64, chunk=chunk)
        eng.submit(Request(0, [4, 3, 2, 1, 2, 3, 4], max_new=5))
        outs.append(eng.run()[0].generated)
    assert all(o == outs[0] for o in outs), outs


# ---------------------------------------------------------------------------
# Per-row masked ring write (the cache primitive under chunked prefill)
# ---------------------------------------------------------------------------

def test_ring_write_per_row_matches_static_and_masks():
    from repro.models.layers import ring_write
    B, T, S, H, D = 3, 16, 5, 2, 4
    buf = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, D))
    val = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D))
    # row-uniform contiguous prefill: per_row path == static path
    pos = jnp.broadcast_to(jnp.arange(4, 4 + S)[None], (B, S))
    np.testing.assert_allclose(np.asarray(ring_write(buf, val, pos)),
                               np.asarray(ring_write(buf, val, pos,
                                                     per_row=True)))
    # full-length wrap (S == T ring prefill)
    val2 = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, D))
    pos2 = jnp.broadcast_to(jnp.arange(10, 10 + T)[None], (B, T))
    np.testing.assert_allclose(np.asarray(ring_write(buf, val2, pos2)),
                               np.asarray(ring_write(buf, val2, pos2,
                                                     per_row=True)))
    # mixed per-row starts (one wrapping) + a fully masked row
    pos3 = jnp.stack([jnp.arange(2, 2 + S), jnp.full((S,), -1),
                      jnp.arange(14, 14 + S)])
    got = np.asarray(ring_write(buf, val, pos3, per_row=True))
    exp = np.asarray(buf).copy()
    for s in range(S):
        exp[0, (2 + s) % T] = np.asarray(val)[0, s]
        exp[2, (14 + s) % T] = np.asarray(val)[2, s]
    np.testing.assert_allclose(got, exp)   # row 1 (masked) untouched


# ---------------------------------------------------------------------------
# Input validation
# ---------------------------------------------------------------------------

def test_empty_prompt_rejected():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(5), cfg)
    eng = ServingEngine(params, cfg, slots=1, cache_len=16)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(0, [], max_new=4))
    assert not eng.queue


def test_oversize_prompt_rejected_for_full_attention():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(5), cfg)
    eng = ServingEngine(params, cfg, slots=1, cache_len=8)
    with pytest.raises(ValueError, match="not wrap"):
        eng.submit(Request(0, list(range(9)), max_new=1))


def test_attention_free_long_prompt_served():
    """Recurrent models (RWKV) have no cache_len-sized buffer — a prompt
    longer than cache_len must be admitted and stay parity-correct (the
    context bound applies to full-attention caches only).  150 tokens
    also exceeds SCAN_CHUNK=128 with a remainder, regression-covering the
    padded-scan state corruption in _chunked_scan (padded decay steps
    must be state no-ops or generate()'s own prefill carry is wrong)."""
    cfg = get_smoke_config("rwkv6-7b")
    params = init_params(jax.random.PRNGKey(9), cfg)
    prompt = [(i * 5 + 1) % cfg.vocab_size for i in range(150)]
    eng = ServingEngine(params, cfg, slots=2, cache_len=64, chunk=16)
    eng.submit(Request(0, prompt, max_new=4))     # 150 > cache_len
    out = eng.run()[0].generated
    ref = generate(params, cfg, jnp.asarray([prompt], jnp.int32),
                   max_new=4)[0, len(prompt):].tolist()
    assert out == ref, (out, ref)


def test_chunked_scan_padded_tail_is_state_noop():
    """_chunked_scan pads length to a chunk multiple; the padded steps
    must not advance the carry (a decay step on zero input is not the
    identity)."""
    from repro.models.ssm import _chunked_scan
    step = lambda h, x: (h + 1.0, h)
    for L in (5, 128, 150, 257):
        h, ys = _chunked_scan(step, jnp.zeros(()), jnp.zeros((L,)), L)
        assert float(h) == L, (L, float(h))
        assert ys.shape[0] == L


@pytest.mark.parametrize("chunk", [16, 80])
def test_swa_ring_wrap_chunked_prefill_parity(chunk):
    """Prompt LONGER than the sliding window: mid-prefill the chunk write
    wraps the SWA ring and evicts slots whose keys are still inside the
    earliest in-chunk queries' windows.  Attention must run against the
    pre-write ring ∥ chunk, so greedy output equals generate() for any
    chunk size (regression: write-then-attend silently diverged here)."""
    cfg = get_smoke_config("gemma3-12b")          # window 64
    params = init_params(jax.random.PRNGKey(7), cfg)
    prompt = [(i * 7 + 3) % cfg.vocab_size for i in range(80)]  # > window
    eng = ServingEngine(params, cfg, slots=1, cache_len=128, chunk=chunk)
    eng.submit(Request(0, prompt, max_new=6))
    out = eng.run()[0].generated
    ref = generate(params, cfg, jnp.asarray([prompt], jnp.int32),
                   max_new=6)[0, len(prompt):].tolist()
    assert out == ref, (chunk, out, ref)


def test_full_attention_ring_wrap_rejected():
    """prompt + max_new beyond cache_len would wrap a full-attention ring
    and silently overwrite early KV — submit() must reject it."""
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(5), cfg)
    eng = ServingEngine(params, cfg, slots=1, cache_len=16)
    with pytest.raises(ValueError, match="not wrap"):
        eng.submit(Request(0, list(range(1, 13)), max_new=10))
    eng.submit(Request(1, list(range(1, 13)), max_new=4))    # exactly fits


def test_warmup_on_busy_engine_preserves_live_slots():
    """warmup() after traffic has started must not clear a live slot's
    cache (the compile-the-reset step may only touch a free slot)."""
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(8), cfg)
    prompt = [3, 1, 4, 1, 5]
    eng = ServingEngine(params, cfg, slots=1, cache_len=32, chunk=4)
    eng.submit(Request(0, prompt, max_new=6))
    eng.tick()                     # admit + first token; slot 0 now live
    eng.warmup()                   # no free slot: must leave cache alone
    eng.run()
    ref = generate(params, cfg, jnp.asarray([prompt], jnp.int32),
                   max_new=6)[0, len(prompt):].tolist()
    assert eng.finished[0].generated == ref


def test_warmup_is_state_noop():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(6), cfg)
    eng = ServingEngine(params, cfg, slots=2, cache_len=32, chunk=4)
    eng.warmup()
    eng.submit(Request(0, [1, 2, 3, 4, 5], max_new=3))
    warm = eng.run()[0].generated
    eng2 = ServingEngine(params, cfg, slots=2, cache_len=32, chunk=4)
    eng2.submit(Request(0, [1, 2, 3, 4, 5], max_new=3))
    assert warm == eng2.run()[0].generated
