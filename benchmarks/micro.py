"""Microbenchmarks: kernel wall times (interpret mode on CPU — relative
numbers only), scheduler/decomposer timings, compression ratios, pipeline
closed-form vs simulator agreement."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


def _time_call(fn, *args, repeat: int = 3) -> float:
    fn(*args)                     # compile/warm
    t0 = time.perf_counter()
    for _ in range(repeat):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / repeat * 1e6  # us


def kernel_bench() -> List[dict]:
    from repro.kernels import ops
    rows = []
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 256, 128), jnp.float32)
    k = jax.random.normal(key, (1, 2, 256, 128), jnp.float32)
    v = jax.random.normal(key, (1, 2, 256, 128), jnp.float32)
    us = _time_call(lambda: ops.flash_attention(q, k, v))
    rows.append({"name": "kernel/flash_attention_256", "us_per_call": us,
                 "derived": f"gqa4:2,interpret"})
    x = jax.random.normal(key, (1 << 20,), jnp.float32)
    us = _time_call(lambda: ops.int8_quantize(x))
    rows.append({"name": "kernel/int8_quantize_1M", "us_per_call": us,
                 "derived": f"ratio={(1<<20)*4/((1<<20)+4*4096):.2f}x"})
    xm = jax.random.normal(key, (1, 128, 256), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (1, 128, 256))) * 0.1
    b = jax.random.normal(key, (1, 128, 16), jnp.float32)
    a = -jnp.exp(jax.random.normal(key, (256, 16)))
    us = _time_call(lambda: ops.mamba_scan(xm, dt, b, b, a, chunk=32,
                                           di_block=128))
    rows.append({"name": "kernel/mamba_scan_128x256", "us_per_call": us,
                 "derived": "interpret"})
    r = jax.random.normal(key, (1, 64, 2, 32), jnp.float32)
    w = jax.nn.sigmoid(jax.random.normal(key, (1, 64, 2, 32)))
    u = jax.random.normal(key, (2, 32)) * 0.1
    us = _time_call(lambda: ops.rwkv_scan(r, r, r, w, u, chunk=16))
    rows.append({"name": "kernel/rwkv_scan_64", "us_per_call": us,
                 "derived": "interpret"})
    return rows


def engine_bench() -> List[dict]:
    """Serving-engine microbench: chunked-prefill admission (vs the seed's
    token-level equivalent, chunk=1) and the batched decode tick."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models.transformer import init_params
    from repro.serve.engine import Request, ServingEngine

    cfg = dataclasses.replace(get_smoke_config("gpt3-24l"), vocab_size=128,
                              d_model=128, d_ff=256, n_heads=4, n_kv_heads=4,
                              head_dim=32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    S, slots, cache_len = 64, 4, 128
    prompt = list(range(1, S + 1))

    def admit_us(chunk: int) -> float:
        eng = ServingEngine(params, cfg, slots=slots, cache_len=cache_len,
                            chunk=chunk)
        eng.warmup()                      # compile both engine shapes
        eng.submit(Request(0, prompt, max_new=1))
        t0 = time.perf_counter()
        eng._admit()
        jax.block_until_ready(eng.caches)
        return (time.perf_counter() - t0) * 1e6

    us_tokenwise = admit_us(1)            # seed behaviour: S jitted calls
    us_chunked = admit_us(16)             # ceil(S/16) = 4 jitted calls
    rows = [{"name": f"engine/admit_{S}tok_chunk16",
             "us_per_call": us_chunked,
             "derived": f"{us_tokenwise/us_chunked:.1f}x_vs_tokenwise"},
            {"name": f"engine/admit_{S}tok_chunk1",
             "us_per_call": us_tokenwise,
             "derived": f"{S}_jit_calls"}]

    eng = ServingEngine(params, cfg, slots=slots, cache_len=cache_len,
                        chunk=16)
    eng.warmup()
    for i in range(slots):
        eng.submit(Request(i, prompt[: 8 + i], max_new=64))
    eng.tick()                            # admissions + first tick
    n = 20
    t0 = time.perf_counter()
    for _ in range(n):
        eng.tick()
    jax.block_until_ready(eng.caches)
    us_tick = (time.perf_counter() - t0) / n * 1e6
    rows.append({"name": f"engine/tick_{slots}slots",
                 "us_per_call": us_tick,
                 "derived": f"{us_tick / slots:.0f}us_per_slot_token"})
    rows.extend(paged_engine_bench(params, cfg))
    return rows


def paged_engine_bench(params, cfg) -> List[dict]:
    """Paged-vs-dense at EQUAL cache memory under heterogeneous prompt
    lengths: the dense engine spends one worst-case ``cache_len`` per
    slot, the paged engine spends per-request pages from a shared pool —
    so at the same byte budget it runs strictly more requests
    concurrently.  Also times admit + decode tick on the paged path
    (gather/scatter overhead vs the dense ring write)."""
    from repro.serve.engine import Request, ServingEngine

    cache_len, page = 64, 8
    long_p = list(range(1, 49))           # 48 prompt + 16 new = worst case
    short_p = [7, 8, 9]                   # 3 prompt + 8 new = 2 pages
    reqs = [(long_p, 16)] + [(short_p, 8)] * 6

    def drive(paged: bool, slots: int):
        eng = ServingEngine(params, cfg, slots=slots, cache_len=cache_len,
                            chunk=16, paged=paged, page_size=page,
                            num_blocks=(3 * cache_len) // page if paged
                            else None)
        eng.warmup()
        for i, (p, mn) in enumerate(reqs):
            eng.submit(Request(i, p, max_new=mn))
        peak, ticks = 0, 0
        t0 = time.perf_counter()
        while True:
            n = eng.tick()
            if not n and not eng.queue:
                break
            peak, ticks = max(peak, n), ticks + 1
        jax.block_until_ready(eng.caches)
        return peak, ticks, (time.perf_counter() - t0) * 1e6

    # equal memory: dense 3 slots x 64 entries == paged 24 pages x 8
    d_peak, d_ticks, d_us = drive(False, 3)
    p_peak, p_ticks, p_us = drive(True, 7)
    rows = [{"name": "engine/paged_concurrency_equal_mem",
             "us_per_call": p_us / max(1, p_ticks),
             "derived": f"peak{p_peak}vs{d_peak}_ticks{p_ticks}vs{d_ticks}"
                        f"_dense{d_us / max(1, d_ticks):.0f}us"}]
    assert p_peak > d_peak, (p_peak, d_peak)

    # paged step overhead at matched occupancy (4 slots, same prompts)
    for paged in (False, True):
        eng = ServingEngine(params, cfg, slots=4, cache_len=cache_len,
                            chunk=16, paged=paged, page_size=page)
        eng.warmup()
        for i in range(4):
            eng.submit(Request(i, long_p[: 8 + i], max_new=48))
        eng.tick()
        n = 20
        t0 = time.perf_counter()
        for _ in range(n):
            eng.tick()
        jax.block_until_ready(eng.caches)
        us = (time.perf_counter() - t0) / n * 1e6
        rows.append({"name": f"engine/tick_4slots_"
                             f"{'paged' if paged else 'dense'}",
                     "us_per_call": us,
                     "derived": f"page{page}" if paged else "ring"})
    return rows


def scheduler_bench() -> List[dict]:
    from repro.core.dag import build_model_dag
    from repro.core.decomposer import decompose_contiguous
    from repro.core.perfmodel import LINK_REGIMES, PerfModel, make_fleet
    from repro.core.scheduler import schedule_loadbalance, tasks_from_parts
    from repro.configs import get_config

    cfg = get_config("gpt3-24l")
    dag = build_model_dag(cfg, batch=32, seq=2048)
    rows = []
    t0 = time.perf_counter()
    parts = decompose_contiguous(dag, 50)
    t_dec = (time.perf_counter() - t0) * 1e6
    rows.append({"name": "core/decompose_50", "us_per_call": t_dec,
                 "derived": f"{len(dag)}ops"})
    nodes = make_fleet([("rtx3080", 30), ("rtx4090", 10), ("rtx4080", 10)],
                       LINK_REGIMES["wan_1gbps"])
    tasks = tasks_from_parts(dag, parts)
    t0 = time.perf_counter()
    sched = schedule_loadbalance(tasks, nodes)
    t_sch = (time.perf_counter() - t0) * 1e6
    # balance quality: makespan vs lower bound
    lb = sum(t.flops for t in tasks) / sum(n.speed for n in nodes)
    rows.append({"name": "core/schedule_lpt_50x50", "us_per_call": t_sch,
                 "derived": f"makespan/LB={sched.makespan/lb:.3f}"})
    return rows


def compression_bench() -> List[dict]:
    from repro.core.compression import CompressionSpec
    n = 10**8   # a 400MB f32 gradient
    rows = []
    for spec in [CompressionSpec("none"), CompressionSpec("topk", ratio=0.01),
                 CompressionSpec("qsgd", levels=256),
                 CompressionSpec("int8"),
                 CompressionSpec("local_sgd", period=8)]:
        by = spec.bytes(n)
        # time to send over 1 Gbps
        rows.append({"name": f"compression/{spec.kind}",
                     "us_per_call": by / (125e6) * 1e6,
                     "derived": f"{4*n/by:.1f}x_smaller"})
    return rows


def pipeline_bench() -> List[dict]:
    from repro.core.pipeline import (StageTimes, pipelined_eq4,
                                     simulate_pipeline)
    rng = np.random.RandomState(0)
    errs = []
    t0 = time.perf_counter()
    for _ in range(100):
        n = rng.randint(2, 20)
        st = StageTimes(list(rng.uniform(0.1, 2, n)),
                        list(rng.uniform(0, 1, n)))
        nb = int(rng.randint(1, 256))
        sim = simulate_pipeline(st, nb)
        eq4 = pipelined_eq4(st, nb)
        errs.append(abs(sim - eq4) / eq4)
    us = (time.perf_counter() - t0) / 100 * 1e6
    return [{"name": "core/pipeline_eq4_vs_sim", "us_per_call": us,
             "derived": f"max_rel_err={max(errs):.2e}"}]
