"""Microbenchmarks: kernel wall times (interpret mode on CPU — relative
numbers only), scheduler/decomposer timings, compression ratios, pipeline
closed-form vs simulator agreement.

``engine_bench`` additionally writes the machine-readable perf
trajectory ``BENCH_engine.json`` at the repo root (decode tok/s dense
vs paged vs paged-kernel, admission latency, peak concurrency at equal
cache memory, per-tick HBM bytes kernel vs gather, the broker-routed
``fleet`` section: placement skew across heterogeneous simulated devices
+ fleet-vs-single-engine throughput, and the ``prefix`` section:
prefix-sharing admission-call/concurrency wins at equal pool memory);
``chaos_bench`` (its own CI step, ``--only chaos``) merges the ``chaos``
degraded-mode fault-tolerance section into the same file, and
``migration_bench`` (``--only migration``) the ``migration``
stateful-failover section — CI uploads it as an artifact so the
trajectory accumulates across PRs."""
from __future__ import annotations

import json
import os
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_engine.json")


def _unwrap_cost(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # some jax versions wrap it
        cost = cost[0] if cost else {}
    return cost


def _time_call(fn, *args, repeat: int = 3) -> float:
    fn(*args)                     # compile/warm
    t0 = time.perf_counter()
    for _ in range(repeat):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / repeat * 1e6  # us


def kernel_bench() -> List[dict]:
    from repro.kernels import ops
    rows = []
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 256, 128), jnp.float32)
    k = jax.random.normal(key, (1, 2, 256, 128), jnp.float32)
    v = jax.random.normal(key, (1, 2, 256, 128), jnp.float32)
    us = _time_call(lambda: ops.flash_attention(q, k, v))
    rows.append({"name": "kernel/flash_attention_256", "us_per_call": us,
                 "derived": f"gqa4:2,interpret"})
    x = jax.random.normal(key, (1 << 20,), jnp.float32)
    us = _time_call(lambda: ops.int8_quantize(x))
    rows.append({"name": "kernel/int8_quantize_1M", "us_per_call": us,
                 "derived": f"ratio={(1<<20)*4/((1<<20)+4*4096):.2f}x"})
    xm = jax.random.normal(key, (1, 128, 256), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (1, 128, 256))) * 0.1
    b = jax.random.normal(key, (1, 128, 16), jnp.float32)
    a = -jnp.exp(jax.random.normal(key, (256, 16)))
    us = _time_call(lambda: ops.mamba_scan(xm, dt, b, b, a, chunk=32,
                                           di_block=128))
    rows.append({"name": "kernel/mamba_scan_128x256", "us_per_call": us,
                 "derived": "interpret"})
    r = jax.random.normal(key, (1, 64, 2, 32), jnp.float32)
    w = jax.nn.sigmoid(jax.random.normal(key, (1, 64, 2, 32)))
    u = jax.random.normal(key, (2, 32)) * 0.1
    us = _time_call(lambda: ops.rwkv_scan(r, r, r, w, u, chunk=16))
    rows.append({"name": "kernel/rwkv_scan_64", "us_per_call": us,
                 "derived": "interpret"})
    return rows


def engine_bench() -> List[dict]:
    """Serving-engine microbench: chunked-prefill admission (vs the seed's
    token-level equivalent, chunk=1) and the batched decode tick.  Also
    writes the ``BENCH_engine.json`` perf trajectory at the repo root."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models.transformer import init_params
    from repro.serve.engine import Request, ServingEngine

    summary: dict = {"schema": 1, "backend": jax.default_backend()}
    cfg = dataclasses.replace(get_smoke_config("gpt3-24l"), vocab_size=128,
                              d_model=128, d_ff=256, n_heads=4, n_kv_heads=4,
                              head_dim=32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    S, slots, cache_len = 64, 4, 128
    prompt = list(range(1, S + 1))

    def admit_us(chunk: int) -> float:
        eng = ServingEngine(params, cfg, slots=slots, cache_len=cache_len,
                            chunk=chunk)
        eng.warmup()                      # compile both engine shapes
        eng.submit(Request(0, prompt, max_new=1))
        t0 = time.perf_counter()
        eng._admit()
        jax.block_until_ready(eng.caches)
        return (time.perf_counter() - t0) * 1e6

    us_tokenwise = admit_us(1)            # seed behaviour: S jitted calls
    us_chunked = admit_us(16)             # ceil(S/16) = 4 jitted calls
    summary["admit"] = {"prompt_tokens": S, "chunked_us": us_chunked,
                        "tokenwise_us": us_tokenwise,
                        "speedup": us_tokenwise / us_chunked}
    rows = [{"name": f"engine/admit_{S}tok_chunk16",
             "us_per_call": us_chunked,
             "derived": f"{us_tokenwise/us_chunked:.1f}x_vs_tokenwise"},
            {"name": f"engine/admit_{S}tok_chunk1",
             "us_per_call": us_tokenwise,
             "derived": f"{S}_jit_calls"}]

    eng = ServingEngine(params, cfg, slots=slots, cache_len=cache_len,
                        chunk=16)
    eng.warmup()
    for i in range(slots):
        eng.submit(Request(i, prompt[: 8 + i], max_new=64))
    eng.tick()                            # admissions + first tick
    n = 20
    t0 = time.perf_counter()
    for _ in range(n):
        eng.tick()
    jax.block_until_ready(eng.caches)
    us_tick = (time.perf_counter() - t0) / n * 1e6
    rows.append({"name": f"engine/tick_{slots}slots",
                 "us_per_call": us_tick,
                 "derived": f"{us_tick / slots:.0f}us_per_slot_token"})
    rows.extend(paged_engine_bench(params, cfg, summary))
    rows.extend(paged_kernel_bench(summary))
    rows.extend(fleet_bench(summary))
    rows.extend(prefix_share_bench(summary))
    with open(BENCH_JSON, "w") as f:
        json.dump(summary, f, indent=1, default=float)
    rows.append({"name": "engine/bench_json", "us_per_call": "",
                 "derived": os.path.basename(BENCH_JSON)})
    return rows


def paged_engine_bench(params, cfg, summary: Optional[dict] = None
                       ) -> List[dict]:
    """Paged-vs-dense at EQUAL cache memory under heterogeneous prompt
    lengths: the dense engine spends one worst-case ``cache_len`` per
    slot, the paged engine spends per-request pages from a shared pool —
    so at the same byte budget it runs strictly more requests
    concurrently.  Also times the decode tick at matched occupancy
    across all three decode paths: dense rings, paged gather (scan
    path), and the fused paged-decode Pallas kernel."""
    from repro.serve.engine import Request, ServingEngine

    summary = summary if summary is not None else {}
    cache_len, page = 64, 8
    long_p = list(range(1, 49))           # 48 prompt + 16 new = worst case
    short_p = [7, 8, 9]                   # 3 prompt + 8 new = 2 pages
    reqs = [(long_p, 16)] + [(short_p, 8)] * 6

    def drive(paged: bool, slots: int):
        eng = ServingEngine(params, cfg, slots=slots, cache_len=cache_len,
                            chunk=16, paged=paged, page_size=page,
                            num_blocks=(3 * cache_len) // page if paged
                            else None)
        eng.warmup()
        for i, (p, mn) in enumerate(reqs):
            eng.submit(Request(i, p, max_new=mn))
        peak, ticks = 0, 0
        t0 = time.perf_counter()
        while True:
            n = eng.tick()
            if not n and not eng.queue:
                break
            peak, ticks = max(peak, n), ticks + 1
        jax.block_until_ready(eng.caches)
        return peak, ticks, (time.perf_counter() - t0) * 1e6

    # equal memory: dense 3 slots x 64 entries == paged 24 pages x 8
    d_peak, d_ticks, d_us = drive(False, 3)
    p_peak, p_ticks, p_us = drive(True, 7)
    summary["peak_concurrency_equal_mem"] = {"dense": d_peak,
                                             "paged": p_peak}
    rows = [{"name": "engine/paged_concurrency_equal_mem",
             "us_per_call": p_us / max(1, p_ticks),
             "derived": f"peak{p_peak}vs{d_peak}_ticks{p_ticks}vs{d_ticks}"
                        f"_dense{d_us / max(1, d_ticks):.0f}us"}]
    assert p_peak > d_peak, (p_peak, d_peak)

    # decode tick at matched occupancy (4 slots, same prompts), all
    # three decode paths; tok/s = decoded tokens per wall second
    summary["decode_tick_4slots"] = {}
    modes = [("dense", False, False), ("paged", True, False),
             ("paged_kernel", True, True)]
    for mode, paged, use_kernel in modes:
        eng = ServingEngine(params, cfg, slots=4, cache_len=cache_len,
                            chunk=16, paged=paged, page_size=page,
                            use_kernel=use_kernel)
        eng.warmup()
        for i in range(4):
            eng.submit(Request(i, long_p[: 8 + i], max_new=48))
        eng.tick()
        n = 20
        t0 = time.perf_counter()
        for _ in range(n):
            eng.tick()
        jax.block_until_ready(eng.caches)
        us = (time.perf_counter() - t0) / n * 1e6
        summary["decode_tick_4slots"][mode] = {
            "us_per_tick": us, "tok_s": 4 / us * 1e6}
        rows.append({"name": f"engine/tick_4slots_{mode}",
                     "us_per_call": us,
                     "derived": f"{4 / us * 1e6:.0f}tok_s"})
    return rows


def paged_kernel_bench(summary: Optional[dict] = None) -> List[dict]:
    """Fused Pallas paged-decode attention vs the chunked-gather scan
    path, across pool sizes: per-decode-tick HBM bytes and wall-clock
    latency.

    Bytes: the gather path is costed by XLA on its compiled step
    (``compiled.cost_analysis()['bytes accessed']`` — it materializes
    and re-reads the gathered (B, C, Hkv, D) K/V copy every
    online-softmax chunk).  The kernel path's bytes are its static DMA
    schedule (``paged_attention_cost`` — the ``pl.CostEstimate``
    attached to the ``pallas_call``, which is exactly what
    ``cost_analysis()`` reports for the fused op when compiled through
    Mosaic): each pool page read once per kv head, q/out once per
    (slot, head), no intermediate copy.  The HARDWARE claim — the one
    asserted — uses the compiled-mode layout (``interpret=False``:
    head dims lane-padded to 128, the blocks Mosaic actually moves);
    the tighter interpret-layout bytes and the interpret emulation's
    own XLA count (which measures the interpreter's loop-carried
    copies, not the kernel) are reported for transparency.  Asserts
    the kernel moves STRICTLY fewer HBM bytes at every pool size."""
    from functools import partial

    from repro.kernels.paged_attention import paged_attention_cost
    from repro.models.layers import attention

    summary = summary if summary is not None else {}
    rows = []
    traj = summary.setdefault("paged_kernel_hbm", [])
    B, Hq, Hkv, D, page = 4, 8, 2, 64, 16
    key = jax.random.PRNGKey(0)
    for n_cols in (8, 64, 256):
        N = B * n_cols
        T = n_cols * page
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, 1, Hq, D), jnp.bfloat16)
        k = jax.random.normal(ks[1], (N, page, Hkv, D), jnp.bfloat16)
        v = jax.random.normal(ks[2], (N, page, Hkv, D), jnp.bfloat16)
        # fully-populated pool: slot b's column c holds block b*n_cols+c
        pos = ((jnp.arange(N)[:, None] % n_cols) * page
               + jnp.arange(page)).astype(jnp.int32)
        table = (jnp.arange(B * n_cols, dtype=jnp.int32)
                 .reshape(B, n_cols))
        q_pos = jnp.full((B, 1), T - 1, jnp.int32)

        gather = jax.jit(partial(attention, use_kernel=False))
        kern = jax.jit(partial(attention, use_kernel=True))
        compiled = gather.lower(q, k, v, q_pos, pos, table=table).compile()
        gather_bytes = _unwrap_cost(compiled).get("bytes accessed", 0.0)
        kernel_bytes = paged_attention_cost(
            q, k, v, table, interpret=False).bytes_accessed
        assert kernel_bytes < gather_bytes, (
            f"paged kernel must move strictly fewer HBM bytes than the "
            f"gather path: {kernel_bytes} vs {gather_bytes} at "
            f"n_cols={n_cols}")
        interp_bytes = paged_attention_cost(
            q, k, v, table, interpret=True).bytes_accessed
        ci = kern.lower(q, k, v, q_pos, pos, table=table).compile()
        icost = _unwrap_cost(ci)
        us_g = _time_call(lambda: gather(q, k, v, q_pos, pos, table=table))
        us_k = _time_call(lambda: kern(q, k, v, q_pos, pos, table=table))
        traj.append({"n_cols": n_cols, "kv_positions": T,
                     "gather_bytes": gather_bytes,
                     "kernel_bytes_compiled_layout": kernel_bytes,
                     "bytes_ratio": gather_bytes / kernel_bytes,
                     "kernel_bytes_interpret_layout": interp_bytes,
                     "interpret_emulation_bytes":
                         icost.get("bytes accessed", 0.0),
                     "gather_us": us_g, "kernel_interpret_us": us_k})
        rows.append({"name": f"kernel/paged_decode_{T}kv",
                     "us_per_call": us_k,
                     "derived": f"hbm{gather_bytes/kernel_bytes:.1f}x_"
                                f"less_gather{us_g:.0f}us"})
    return rows


def fleet_bench(summary: Optional[dict] = None) -> List[dict]:
    """Broker-routed fleet vs a single engine on a uniform workload.

    Two replicas on heterogeneous simulated devices (rtx4090 vs rtx3080)
    behind one FIFO queue: Eq. 2 placement must skew STRICTLY toward the
    faster device (asserted — requests served proportional to
    ``DEVICE_CATALOG`` speeds), and the fleet's wall-clock throughput is
    reported against a single engine of the same per-replica size
    serving the whole workload.  Standalone runs merge the ``fleet``
    section into the existing ``BENCH_engine.json``; under
    ``engine_bench`` the caller owns the write."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models.transformer import init_params
    from repro.serve.engine import Request, ServingEngine
    from repro.serve.router import FleetRouter, sim_node

    standalone = summary is None
    if standalone:
        summary = {}
        if os.path.exists(BENCH_JSON):
            with open(BENCH_JSON) as f:
                summary = json.load(f)
    cfg = dataclasses.replace(get_smoke_config("gpt3-24l"), vocab_size=128,
                              d_model=128, d_ff=256, n_heads=4, n_kv_heads=4,
                              head_dim=32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_req = 12
    reqs = [(list(range(1, 9)), 8) for _ in range(n_req)]   # uniform

    def engine():
        return ServingEngine(params, cfg, slots=2, cache_len=64, chunk=8,
                             paged=True, page_size=16)

    # single-engine baseline: one replica-sized engine takes everything
    single = engine()
    single.warmup()
    for i, (p, mn) in enumerate(reqs):
        single.submit(Request(i, p, max_new=mn))
    t0 = time.perf_counter()
    single.run()
    jax.block_until_ready(single.caches)
    single_s = time.perf_counter() - t0

    router = FleetRouter([(engine(), sim_node("rtx4090")),
                          (engine(), sim_node("rtx3080"))])
    for rep in router.replicas:
        rep.engine.warmup()
    for i, (p, mn) in enumerate(reqs):
        router.submit(Request(i, p, max_new=mn))
    t0 = time.perf_counter()
    done = router.run()
    for rep in router.replicas:
        jax.block_until_ready(rep.engine.caches)
    fleet_s = time.perf_counter() - t0

    assert len(done) == n_req, (len(done), n_req)
    fast, slow = router.replicas
    assert fast.node.speed > slow.node.speed
    assert len(fast.served) > len(slow.served) > 0, (
        f"Eq. 2 placement must skew toward the faster simulated device "
        f"on a uniform workload, with BOTH devices participating: "
        f"rtx4090 served {len(fast.served)} vs rtx3080 {len(slow.served)}")
    toks = sum(len(r.generated) for r in done)
    summary["fleet"] = {
        "replicas": [{"device": rep.node.device.name,
                      "speed_flops": rep.node.speed,
                      "served": len(rep.served)}
                     for rep in router.replicas],
        "requests": n_req,
        "placement_skew": len(fast.served) / len(slow.served),
        "speed_ratio": fast.node.speed / slow.node.speed,
        "fleet_tok_s": toks / fleet_s,
        "single_engine_tok_s": toks / single_s,
        "throughput_vs_single": single_s / fleet_s,
        "held_ticks": router.stats["held"],
    }
    if standalone:
        with open(BENCH_JSON, "w") as f:
            json.dump(summary, f, indent=1, default=float)
    return [{"name": "fleet/placement_skew_rtx4090_vs_rtx3080",
             "us_per_call": fleet_s / max(1, toks) * 1e6,
             "derived": f"served{len(fast.served)}vs{len(slow.served)}_"
                        f"speed{fast.node.speed / slow.node.speed:.2f}x"},
            {"name": "fleet/throughput_vs_single_engine",
             "us_per_call": single_s / max(1, toks) * 1e6,
             "derived": f"{single_s / fleet_s:.2f}x_2replicas"}]


def prefix_share_bench(summary: Optional[dict] = None) -> List[dict]:
    """Prefix-sharing paged cache vs independent admissions (the ISSUE 7
    acceptance bench): 8 requests over the same full-page system prefix,
    equal pool memory.

    Asserted: (a) admission runs STRICTLY fewer jitted prefill calls than
    8 independent admissions (shared pages are attached, not re-run),
    (b) peak concurrent requests STRICTLY exceeds the no-sharing paged
    engine (shared pages are excluded from the up-front reservation),
    and (c) every request's greedy output is bitwise-equal to the
    non-shared engine — including the request whose prompt extends
    another's (its first divergent append copy-on-writes) and requests
    requeued through a fleet replica failure (``drain_requests``
    preserves their prefix digests, the survivor re-shares).  Standalone
    runs merge the ``prefix`` section into ``BENCH_engine.json``; under
    ``engine_bench`` the caller owns the write."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models.transformer import init_params
    from repro.serve.engine import Request, ServingEngine
    from repro.serve.router import FleetRouter, sim_node

    standalone = summary is None
    if standalone:
        summary = {}
        if os.path.exists(BENCH_JSON):
            with open(BENCH_JSON) as f:
                summary = json.load(f)
    cfg = dataclasses.replace(get_smoke_config("gpt3-24l"), vocab_size=128,
                              d_model=128, d_ff=256, n_heads=4, n_kv_heads=4,
                              head_dim=32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    page, pool = 8, 12
    prefix = list(range(1, 17))               # two full shared pages
    prompts = [prefix + [100 + i] for i in range(8)]
    prompts[1] = prompts[0] + [60]            # extends req 0 -> CoW on append

    def drive(share: bool):
        eng = ServingEngine(params, cfg, slots=8, cache_len=64, chunk=4,
                            paged=True, page_size=page, num_blocks=pool,
                            share_prefix=share)
        eng.warmup()
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, max_new=8))
        peak, ticks = 0, 0
        t0 = time.perf_counter()
        while eng.tick() or eng.queue:
            peak, ticks = max(peak, eng.n_active), ticks + 1
        jax.block_until_ready(eng.caches)
        wall = time.perf_counter() - t0
        outs = {r.req_id: r.generated for r in eng.finished}
        return eng, outs, peak, ticks, wall

    ind, ind_out, ind_peak, ind_ticks, ind_s = drive(False)
    shr, shr_out, shr_peak, shr_ticks, shr_s = drive(True)
    ind_calls = ind.stats["prefill_calls"]
    shr_calls = shr.stats["prefill_calls"]
    assert shr_calls < ind_calls, (
        f"prefix sharing must run strictly fewer jitted prefill calls "
        f"than independent admissions: {shr_calls} vs {ind_calls}")
    assert shr_peak > ind_peak, (
        f"prefix sharing must raise peak concurrency at equal pool "
        f"memory: {shr_peak} vs {ind_peak}")
    assert shr.stats["cow_copies"] >= 1     # the divergent-append copy
    assert shr_out == ind_out, "sharing changed greedy decode output"

    # fleet failover requeue: both same-prefix requests co-locate on
    # replica 0 (near-tie affinity), die with it mid-decode, requeue
    # WITH their prefix digests, re-share on the survivor — outputs must
    # match the non-shared single-engine run bitwise
    def rep():
        return ServingEngine(params, cfg, slots=2, cache_len=64, chunk=4,
                             paged=True, page_size=page)
    router = FleetRouter([(rep(), sim_node("rtx4090")),
                          (rep(), sim_node("rtx4090"))])
    router.submit(Request(0, prompts[0], max_new=18))
    router.tick()
    router.submit(Request(2, prompts[2], max_new=40))
    for _ in range(3):
        router.tick()
    victims = [rid for rid, pl in router.placements.items() if pl == [0]]
    router.fail_replica(0)
    fleet_out = {r.req_id: r.generated for r in router.run()}
    survivor = next(r for r in router.replicas if r.alive)
    assert len(victims) == 2 and survivor.engine.stats["shared_pages"] > 0
    assert fleet_out[0][:8] == ind_out[0] and fleet_out[2][:8] == ind_out[2], \
        "failover requeue changed greedy decode output"

    summary["prefix"] = {
        "requests": len(prompts), "prefix_tokens": len(prefix),
        "page_size": page, "pool_pages": pool,
        "prefill_calls": {"shared": shr_calls, "independent": ind_calls},
        "call_reduction": ind_calls / shr_calls,
        "peak_concurrency_equal_mem": {"shared": shr_peak,
                                       "independent": ind_peak},
        "shared_pages": shr.stats["shared_pages"],
        "shared_tokens": shr.stats["shared_tokens"],
        "cow_copies": shr.stats["cow_copies"],
        "ticks": {"shared": shr_ticks, "independent": ind_ticks},
        "wall_s": {"shared": shr_s, "independent": ind_s},
        "bitwise_equal": True,
        "failover_requeue": {"victims": len(victims),
                             "survivor_shared_pages":
                                 survivor.engine.stats["shared_pages"],
                             "bitwise_equal": True},
    }
    if standalone:
        with open(BENCH_JSON, "w") as f:
            json.dump(summary, f, indent=1, default=float)
    return [{"name": "engine/prefix_share_8req",
             "us_per_call": shr_s / max(1, shr_ticks) * 1e6,
             "derived": f"calls{shr_calls}vs{ind_calls}_"
                        f"peak{shr_peak}vs{ind_peak}_cow"
                        f"{shr.stats['cow_copies']}"},
            {"name": "engine/prefix_share_failover_requeue",
             "us_per_call": "",
             "derived": f"requeued{len(victims)}_reshared"
                        f"{survivor.engine.stats['shared_pages']}pages"}]


def chaos_bench(summary: Optional[dict] = None) -> List[dict]:
    """Degraded-mode fault tolerance under a mixed fault schedule (the
    ISSUE 8 acceptance bench): crash + straggle + partition +
    pool_pressure over a 3-replica fleet, plus a poisoned request whose
    replica is killed until its retry budget runs out.

    Asserted: (a) zero dropped/duplicated requests — every submitted
    req_id terminates exactly once across completed + failed; (b) every
    survivor's greedy output is bitwise-identical to a no-fault
    reference run; (c) requests in flight on the partitioned replica
    resume after heal with no re-dispatch and no re-prefill; (d) the
    poisoned request exhausts its retry budget with outcome
    ``failed_retries`` while the rest of the workload completes.
    The fault schedule is built mid-run against replicas that are
    actually alive and loaded, so the bench stays deterministic without
    hard-coding placement.  Standalone runs merge the ``chaos`` section
    into ``BENCH_engine.json`` (CI runs ``--only chaos``)."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models.transformer import init_params
    from repro.serve.engine import Request, ServingEngine
    from repro.serve.faults import Fault, FaultPlan
    from repro.serve.router import FleetRouter

    standalone = summary is None
    if standalone:
        summary = {}
        if os.path.exists(BENCH_JSON):
            with open(BENCH_JSON) as f:
                summary = json.load(f)
    cfg = dataclasses.replace(get_smoke_config("gpt3-24l"), vocab_size=128,
                              d_model=128, d_ff=256, n_heads=4, n_kv_heads=4,
                              head_dim=32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_req = 10

    def eng():
        return ServingEngine(params, cfg, slots=2, cache_len=64, chunk=8,
                             paged=True, page_size=16)

    def reqs():
        # poison first so its replica kills land while the regular
        # workload is still in flight; staggered max_new so completions
        # don't all line up on one tick
        rs = [Request(n_req, [(7 * j + 1) % cfg.vocab_size
                              for j in range(6)],
                      max_new=40, max_retries=1)]        # the poison pill
        rs += [Request(i, [(3 + 5 * i + j) % cfg.vocab_size
                           for j in range(4 + i % 3)],
                       max_new=8 + 3 * (i % 5))
               for i in range(n_req)]
        return rs

    def fleet(plan=None):
        return FleetRouter(
            [(eng(), d) for d in ("rtx4090", "rtx3080", "rtx3080")],
            standby=[(eng(), "rtx3080"), (eng(), "rtx3080")],
            fault_plan=plan)

    # --- no-fault reference -------------------------------------------
    ref_router = fleet()
    for r in reqs():
        ref_router.submit(r)
    t0 = time.perf_counter()
    ref = ref_router.run()
    calm_s = time.perf_counter() - t0
    assert ref.ok and len(ref.completed) == n_req + 1
    ref_out = {r.req_id: list(r.generated) for r in ref.completed}
    calm_ticks = ref.ticks

    # --- chaos run ----------------------------------------------------
    plan = FaultPlan()
    router = fleet(plan)
    work = reqs()
    poison = work[0]
    for r in work:
        router.submit(r)
    kills = 0
    part_rep = None
    frozen = None
    frozen_pl = None
    t0 = time.perf_counter()
    while router.outstanding() and router.tick_count < 600:
        router.tick()
        if kills < 2 and poison.outcome is None:
            # phase 1: kill whichever replica hosts the poison, twice —
            # past max_retries=1 the second requeue fails it
            host = next((rep for rep in router.replicas if rep.alive
                         and any(a is poison for a in rep.engine.active)),
                        None)
            if host is not None:
                router.fail_replica(host.replica_id)
                kills += 1
        elif poison.outcome is not None and part_rep is None:
            # phase 2: partition the busiest live replica, straggle and
            # pressure the next-busiest, crash it once it recovers.
            # Wait until requeued work is actually back in flight so the
            # partition freezes something.
            live = [rep for rep in router.replicas if rep.alive]
            cand = max(live, key=lambda rep: rep.engine.n_active)
            if cand.engine.n_active == 0:
                continue
            part_rep = cand
            straggler = max((rep for rep in live if rep is not part_rep),
                            key=lambda rep: rep.engine.n_active)
            t = router.tick_count           # the tick about to run
            plan.add(Fault(tick=t, replica_id=part_rep.replica_id,
                           kind="partition", duration=5))
            plan.add(Fault(tick=t, replica_id=straggler.replica_id,
                           kind="straggle", factor=6.0, duration=8))
            plan.add(Fault(tick=t + 1, replica_id=straggler.replica_id,
                           kind="pool_pressure", pages=8, duration=6))
            plan.add(Fault(tick=t + 9, replica_id=straggler.replica_id,
                           kind="crash"))
        elif part_rep is not None and frozen is None:
            # partition just landed: snapshot what it froze in place
            frozen = {a.req_id for a in part_rep.engine.active
                      if a is not None}
            frozen_pl = {rid: list(router.placements[rid])
                         for rid in frozen}
    res = router.run(max_ticks=600)
    chaos_s = time.perf_counter() - t0
    chaos_ticks = router.tick_count
    st = router.stats

    ids = sorted([r.req_id for r in res.completed]
                 + [r.req_id for r in res.failed])
    assert ids == list(range(n_req + 1)), \
        f"requests dropped or duplicated: {ids}"
    assert res.failed == [poison] and poison.outcome == "failed_retries", \
        f"poison outcome {poison.outcome!r}, failed={res.failed}"
    assert kills == 2 and poison.retries == 2
    for r in res.completed:
        assert list(r.generated) == ref_out[r.req_id], \
            f"chaos changed greedy output of req {r.req_id}"
    assert frozen, "partition target held no in-flight work"
    for rid in frozen:
        # frozen requests finish where they froze: no new placement
        # after the partition, terminal outcome ok
        assert router.placements[rid] == frozen_pl[rid]
        assert res.traces[rid]["outcome"] == "ok"
    # every arrival on the partitioned engine — prompt admission or
    # migrated import — is accounted for by exactly one router
    # placement -> heal never re-prefilled
    assert (part_rep.engine.stats["admitted"]
            + part_rep.engine.stats["imported"]) == sum(
        pl.count(part_rep.replica_id)
        for pl in router.placements.values())
    assert st["partitions"] == 1 and st["partition_heals"] == 1
    assert st["straggles"] >= 1 and st["soft_drains"] >= 1
    assert st["pool_pressure"] >= 1 and st["injected_crashes"] >= 1
    assert st["failures"] >= 3          # 2 poison kills + injected crash

    toks_calm = sum(len(r.generated) for r in ref.completed)
    toks_chaos = sum(len(r.generated) for r in res.completed)
    goodput_calm = toks_calm / max(1, calm_ticks)
    goodput_chaos = toks_chaos / max(1, chaos_ticks)
    summary["chaos"] = {
        "requests": n_req + 1, "poison_req": n_req,
        "fault_kinds": ["crash", "straggle", "partition", "pool_pressure"],
        "outcomes": res.outcomes(),
        "ticks": {"calm": calm_ticks, "chaos": chaos_ticks},
        "goodput_tok_per_tick": {"calm": goodput_calm,
                                 "chaos": goodput_chaos},
        "retries_total": sum(tr["retries"] for tr in res.traces.values()),
        "requeued": st["requeued"],
        "soft_drains": st["soft_drains"],
        "preempted": st["preempted"],
        "partition_heals": st["partition_heals"],
        "injected_crashes": st["injected_crashes"],
        "manual_kills": kills,
        "poison_retries": poison.retries,
        "bitwise_equal_survivors": True,
        "partition_resume_without_reprefill": True,
        "wall_s": {"calm": calm_s, "chaos": chaos_s},
    }
    if standalone:
        with open(BENCH_JSON, "w") as f:
            json.dump(summary, f, indent=1, default=float)
    return [{"name": "chaos/mixed_fault_schedule",
             "us_per_call": chaos_s / max(1, chaos_ticks) * 1e6,
             "derived": f"ok{len(res.completed)}_failed{len(res.failed)}_"
                        f"heals{st['partition_heals']}_"
                        f"drains{st['soft_drains']}"},
            {"name": "chaos/goodput_vs_calm",
             "us_per_call": calm_s / max(1, calm_ticks) * 1e6,
             "derived": f"{goodput_chaos / goodput_calm:.2f}x_tok_per_tick"}]


def migration_bench(summary: Optional[dict] = None) -> List[dict]:
    """Stateful failover (ISSUE 10 acceptance bench): verified KV page
    migration and router decode-state snapshots, so faults stop costing
    re-prefill.

    Asserted: (a) soft-drain AND load-rebalance recover mid-decode with
    ZERO re-prefilled tokens — every request is prompt-admitted exactly
    once fleet-wide, migrated arrivals attach via ``import_state``, and
    no victim pays a retry; (b) a crash with router snapshots enabled
    re-decodes only the tokens generated since the last snapshot — the
    engines' ``resumed_tokens`` equals the total snapshot length at the
    kill; (c) a ``corrupt``-faulted transfer is rejected by the
    chained-crc32 verification and falls back to requeue-from-prompt,
    the victims still completing bitwise-identical to a no-fault run.
    Standalone runs merge the ``migration`` section into
    ``BENCH_engine.json`` (CI runs ``--only migration``)."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models.transformer import init_params
    from repro.serve.engine import Request, ServingEngine
    from repro.serve.faults import Fault, FaultPlan
    from repro.serve.router import FleetRouter

    standalone = summary is None
    if standalone:
        summary = {}
        if os.path.exists(BENCH_JSON):
            with open(BENCH_JSON) as f:
                summary = json.load(f)
    cfg = dataclasses.replace(get_smoke_config("gpt3-24l"), vocab_size=128,
                              d_model=128, d_ff=256, n_heads=4, n_kv_heads=4,
                              head_dim=32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_req = 3

    def eng(cache_len=64):
        return ServingEngine(params, cfg, slots=4, cache_len=cache_len,
                             chunk=8, paged=True, page_size=16)

    def reqs(max_new=16):
        return [Request(i, [3 + i] * 20, max_new=max_new)
                for i in range(n_req)]

    def admitted(router):
        return sum(r.engine.stats["admitted"] for r in router.replicas)

    def retries_total(res):
        return sum(tr["retries"] for tr in res.traces.values())

    # --- no-fault reference (shared by every scenario) ----------------
    ref_router = FleetRouter([(eng(), "rtx4090"), (eng(), "rtx3080")])
    for r in reqs():
        ref_router.submit(r)
    ref = ref_router.run()
    assert ref.ok and len(ref.completed) == n_req
    ref_out = {r.req_id: list(r.generated) for r in ref.completed}

    # --- (a1) soft-drain migrates mid-decode: zero re-prefill ---------
    plan = FaultPlan([Fault(2, 0, "straggle", factor=8.0, duration=10)])
    router = FleetRouter([(eng(), "rtx4090"), (eng(), "rtx3080")],
                         fault_plan=plan)
    for r in reqs():
        router.submit(r)
    t0 = time.perf_counter()
    res = router.run(max_ticks=300)
    drain_s = time.perf_counter() - t0
    drain_ticks = res.ticks
    assert router.stats["soft_drains"] >= 1
    drain_migr = router.stats["migrations"]
    assert drain_migr >= 1, "soft-drain must migrate with free peer slots"
    # zero re-prefilled tokens: each request prompt-admitted exactly
    # once across the whole fleet, and migration cost no retry budget
    assert admitted(router) == n_req, \
        f"re-prefill happened: {admitted(router)} admissions for {n_req}"
    assert retries_total(res) == 0
    for r in res.completed:
        assert list(r.generated) == ref_out[r.req_id], \
            f"migration changed greedy output of req {r.req_id}"

    # --- (a2) load-rebalance migrates the newest off the hot replica --
    e0, e1 = eng(), eng()
    router = FleetRouter([(e0, "rtx4090"), (e1, "rtx4090")],
                         rebalance_every=2, rebalance_factor=1.5)
    for r in reqs():
        e0.submit(r)                       # skew: all load on replica 0
    res = router.run(max_ticks=400)
    rebalances = router.stats["rebalances"]
    assert rebalances >= 1, "skewed load must trigger a rebalance"
    assert admitted(router) == n_req and retries_total(res) == 0
    for r in res.completed:
        assert list(r.generated) == ref_out[r.req_id]

    # --- (b) crash with snapshots: re-decode only post-snapshot -------
    kill_tick = 14
    plan = FaultPlan([Fault(kill_tick, 0, "crash")])
    router = FleetRouter([(eng(96), "rtx4090")],
                         standby=[(eng(96), "rtx4090")],
                         fault_plan=plan, snapshot_every=4)
    crash_reqs = [Request(i, [3 + i] * 20, max_new=40) for i in range(2)]
    for r in crash_reqs:
        router.submit(r)
    snap_lens = {}
    while router.outstanding() and router.tick_count < 500:
        if router.tick_count == kill_tick:
            # the state the router's LAST snapshot actually recorded —
            # everything decoded after this must be re-decoded, nothing
            # decoded before it may be
            snap_lens = {rid: len(toks)
                         for rid, (_, toks) in router._snapshots.items()}
        router.tick()
    res = router.run(max_ticks=500)
    assert router.stats["failures"] == 1
    restores = router.stats["snapshot_restores"]
    assert restores >= 1 and snap_lens
    resumed = sum(r.engine.stats["resumed_tokens"] for r in router.replicas)
    assert resumed == sum(snap_lens.values()), \
        f"resumed {resumed} tokens != snapshot state {snap_lens}"
    for r in res.completed:
        assert len(r.generated) == 40

    # --- (c) corrupt-faulted transfer: rejected, victim bitwise -------
    plan = FaultPlan([Fault(0, 0, "corrupt", duration=300),
                      Fault(2, 0, "straggle", factor=8.0, duration=10)])
    router = FleetRouter([(eng(), "rtx4090"), (eng(), "rtx3080")],
                         fault_plan=plan)
    for r in reqs():
        router.submit(r)
    res = router.run(max_ticks=300)
    rejects = sum(r.engine.stats["import_rejects"] for r in router.replicas)
    assert router.stats["migrations"] == 0, \
        "a corrupt-flipped payload must never import"
    assert router.stats["migration_fallbacks"] >= 1 and rejects >= 1
    assert sorted(r.req_id for r in res.completed) == list(range(n_req))
    for r in res.completed:
        assert list(r.generated) == ref_out[r.req_id], \
            f"corrupt fallback changed greedy output of req {r.req_id}"

    summary["migration"] = {
        "requests": n_req,
        "drain": {"migrations": drain_migr,
                  "admissions": n_req, "retries": 0,
                  "zero_reprefill": True},
        "rebalance": {"rebalances": rebalances,
                      "admissions": n_req, "retries": 0},
        "crash_snapshot": {"snapshot_every": 4,
                           "restores": restores,
                           "resumed_tokens": resumed,
                           "redecode_only_post_snapshot": True},
        "corrupt": {"import_rejects": rejects,
                    "fallbacks": router.stats["migration_fallbacks"],
                    "bitwise_equal_victims": True},
    }
    if standalone:
        with open(BENCH_JSON, "w") as f:
            json.dump(summary, f, indent=1, default=float)
    return [{"name": "migration/soft_drain_migrate",
             "us_per_call": drain_s / max(1, drain_ticks) * 1e6,
             "derived": f"migr{drain_migr}_admit{n_req}_retries0"},
            {"name": "migration/crash_snapshot_resume",
             "us_per_call": "",
             "derived": f"resumed{resumed}tok_restores{restores}"},
            {"name": "migration/corrupt_fallback",
             "us_per_call": "",
             "derived": f"rejects{rejects}_bitwise_ok"}]


def scheduler_bench() -> List[dict]:
    from repro.core.dag import build_model_dag
    from repro.core.decomposer import decompose_contiguous
    from repro.core.perfmodel import LINK_REGIMES, PerfModel, make_fleet
    from repro.core.scheduler import schedule_loadbalance, tasks_from_parts
    from repro.configs import get_config

    cfg = get_config("gpt3-24l")
    dag = build_model_dag(cfg, batch=32, seq=2048)
    rows = []
    t0 = time.perf_counter()
    parts = decompose_contiguous(dag, 50)
    t_dec = (time.perf_counter() - t0) * 1e6
    rows.append({"name": "core/decompose_50", "us_per_call": t_dec,
                 "derived": f"{len(dag)}ops"})
    nodes = make_fleet([("rtx3080", 30), ("rtx4090", 10), ("rtx4080", 10)],
                       LINK_REGIMES["wan_1gbps"])
    tasks = tasks_from_parts(dag, parts)
    t0 = time.perf_counter()
    sched = schedule_loadbalance(tasks, nodes)
    t_sch = (time.perf_counter() - t0) * 1e6
    # balance quality: makespan vs lower bound
    lb = sum(t.flops for t in tasks) / sum(n.speed for n in nodes)
    rows.append({"name": "core/schedule_lpt_50x50", "us_per_call": t_sch,
                 "derived": f"makespan/LB={sched.makespan/lb:.3f}"})
    return rows


def compression_bench() -> List[dict]:
    from repro.core.compression import CompressionSpec
    n = 10**8   # a 400MB f32 gradient
    rows = []
    for spec in [CompressionSpec("none"), CompressionSpec("topk", ratio=0.01),
                 CompressionSpec("qsgd", levels=256),
                 CompressionSpec("int8"),
                 CompressionSpec("local_sgd", period=8)]:
        by = spec.bytes(n)
        # time to send over 1 Gbps
        rows.append({"name": f"compression/{spec.kind}",
                     "us_per_call": by / (125e6) * 1e6,
                     "derived": f"{4*n/by:.1f}x_smaller"})
    return rows


def pipeline_bench() -> List[dict]:
    from repro.core.pipeline import (StageTimes, pipelined_eq4,
                                     simulate_pipeline)
    rng = np.random.RandomState(0)
    errs = []
    t0 = time.perf_counter()
    for _ in range(100):
        n = rng.randint(2, 20)
        st = StageTimes(list(rng.uniform(0.1, 2, n)),
                        list(rng.uniform(0, 1, n)))
        nb = int(rng.randint(1, 256))
        sim = simulate_pipeline(st, nb)
        eq4 = pipelined_eq4(st, nb)
        errs.append(abs(sim - eq4) / eq4)
    us = (time.perf_counter() - t0) / 100 * 1e6
    return [{"name": "core/pipeline_eq4_vs_sim", "us_per_call": us,
             "derived": f"max_rel_err={max(errs):.2e}"}]
