"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_):
    recs = [json.load(open(f)) for f in sorted(glob.glob(f"{dir_}/*.json"))]
    return [r for r in recs if r.get("status") == "ok"], \
           [r for r in recs if r.get("status") != "ok"]


def dryrun_table(recs):
    out = ["| arch | shape | mesh | chips | lower s | compile s | "
           "per-chip args GB | per-chip out GB | XLA temp GB (host) |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        m = r["memory_analysis"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} "
            f"| {r['lower_s']:.1f} | {r['compile_s']:.1f} "
            f"| {r['per_chip_arg_bytes']/1e9:.2f} "
            f"| {r['per_chip_out_bytes']/1e9:.2f} "
            f"| {(m['temp_size_in_bytes'] or 0)/1e9:.1f} |")
    return "\n".join(out)


def roofline_table(recs, mesh="pod16x16"):
    out = ["| arch | shape | compute s | memory s | collective s | "
           "bottleneck | MODEL_FLOPS | exec FLOPs | useful | note |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        ro = r["roofline"]
        dom = ro["bottleneck"]
        note = {
            "compute": "raise useful ratio (remat policy) / better MXU use",
            "memory": "decode: batch more requests per cache pass; "
                      "quantize cache",
            "collective": "shard_map EP / pin reshards (see §Perf)",
        }[dom]
        out.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.3f} "
            f"| {ro['memory_s']:.3f} | {ro['collective_s']:.3f} "
            f"| **{dom}** | {ro['model_flops']:.3e} "
            f"| {ro['exec_flops']:.3e} | {ro['useful_ratio']:.2f} "
            f"| {note} |")
    return "\n".join(out)


def collective_table(recs, mesh="pod16x16"):
    out = ["| arch | shape | all-gather | all-reduce | reduce-scatter | "
           "all-to-all | permute |", "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        cd = r["roofline"]["coll_detail"]
        row = " | ".join(f"{cd.get(k, 0)/1e9:.1f}" for k in
                         ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute"))
        out.append(f"| {r['arch']} | {r['shape']} | {row} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline", "collectives"])
    args = ap.parse_args()
    recs, errs = load(args.dir)
    if args.section in ("all", "dryrun"):
        print("### Dry-run matrix\n")
        print(dryrun_table(recs))
    if args.section in ("all", "roofline"):
        print("\n### Roofline (single pod, 256 chips)\n")
        print(roofline_table(recs))
    if args.section in ("all", "collectives"):
        print("\n### Collective bytes (global, GB, loop-aware)\n")
        print(collective_table(recs))
    if errs:
        print(f"\nERRORS: {[(e['arch'], e['shape'], e['mesh']) for e in errs]}")


if __name__ == "__main__":
    main()
