"""Reproductions of the paper's tables/figures, one function each.

Fig. 4  — BERT-Large partitioned into 50 sub-DAGs on RTX 3080s.
Fig. 5  — BERT-Large system performance vs link bandwidth/latency:
          50×RTX3080 against 4×H100 (latency and throughput).
Fig. 6  — the same for GPT-3 (24L, hidden 4096).
Table 1 — fleet cost-efficiency (throughput per USD).

All numbers come from the same machinery the paper uses: the analytic
perf model (§3.7) over the block-granular DAG (§3.5), partitioned by the
speed-aware decomposer and evaluated with Eqs. 3/4 (§4).
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs import get_config
from repro.core.dag import build_model_dag
from repro.core.decomposer import decompose_contiguous, part_stats
from repro.core.perfmodel import (DEVICE_CATALOG, LINK_REGIMES, LinkSpec,
                                  PerfModel, make_fleet)
from repro.core.pipeline import estimate_system

# the paper estimates FP (inference) of batches through the pipeline
BATCH = 32
N_BATCHES = 512
SEQ = {"bert-large": 512, "gpt3-24l": 2048}
LAM = 0.75       # scaling-down factor λ_p (§3.7) applied to every fleet

FLEETS = {
    "50xRTX3080": [("rtx3080", 50)],
    "4xH100": [("h100", 4)],
}

SWEEP_LINKS = ["wan_10mbps", "wan_100mbps", "wan_1gbps", "lan_10gbps",
               "nvlink"]


def _estimate(model: str, fleet_spec, link_name: str) -> Dict[str, float]:
    cfg = get_config(model)
    dag = build_model_dag(cfg, batch=BATCH, seq=SEQ[model], kind="inference")
    nodes = make_fleet(fleet_spec, LINK_REGIMES[link_name], lam=LAM)
    pm = PerfModel(nodes)
    return estimate_system(dag, pm, [n.node_id for n in nodes],
                           n_batches=N_BATCHES, batch_size=BATCH)


def fig4_partition() -> List[dict]:
    """Partition BERT-Large over 50 RTX 3080s (Fig. 4)."""
    cfg = get_config("bert-large")
    dag = build_model_dag(cfg, batch=BATCH, seq=512, kind="inference")
    parts = decompose_contiguous(dag, 50)
    stats = part_stats(dag, parts)
    flops = [s["flops"] for s in stats]
    rows = [{
        "name": "fig4/bert_partition",
        "n_stages": len(parts),
        "max_stage_gflops": max(flops) / 1e9,
        "min_stage_gflops": min(f for f in flops if f > 0) / 1e9,
        "balance": (min(f for f in flops if f > 0) / max(flops)),
        "max_stage_param_mb": max(s["param_bytes"] for s in stats) / 1e6,
    }]
    # every stage fits a 3080 (10 GB)
    assert all(s["param_bytes"] < 10e9 for s in stats)
    return rows


def _fig_rows(model: str, tag: str) -> List[dict]:
    rows = []
    for link in SWEEP_LINKS:
        ests = {name: _estimate(model, spec, link)
                for name, spec in FLEETS.items()}
        a, b = ests["50xRTX3080"], ests["4xH100"]
        rows.append({
            "name": f"{tag}/{link}",
            "latency_3080_s": a["latency_s"],
            "latency_h100_s": b["latency_s"],
            "latency_ratio": a["latency_s"] / b["latency_s"],
            "throughput_3080": a["throughput_samples_s"],
            "throughput_h100": b["throughput_samples_s"],
            "throughput_ratio": (a["throughput_samples_s"]
                                 / b["throughput_samples_s"]),
            "bubble_3080": a["bubble_fraction"],
        })
    return rows


def fig5_bert() -> List[dict]:
    return _fig_rows("bert-large", "fig5/bert-large")


def fig6_gpt3() -> List[dict]:
    return _fig_rows("gpt3-24l", "fig6/gpt3-24l")


def table1_cost() -> List[dict]:
    """Throughput per dollar at 1 Gbps (the paper's 'much lower prices'
    argument, Table 1 prices)."""
    rows = []
    for fname, spec in FLEETS.items():
        est = _estimate("bert-large", spec, "wan_1gbps")
        price = sum(DEVICE_CATALOG[d].price_usd * n for d, n in spec)
        rows.append({
            "name": f"table1/{fname}",
            "fleet_price_usd": price,
            "throughput_samples_s": est["throughput_samples_s"],
            "samples_per_s_per_kusd": est["throughput_samples_s"] / price * 1e3,
        })
    return rows


def paper_claims_check() -> List[dict]:
    """The paper's headline: 50×3080 has HIGHER latency but COMPARABLE
    throughput to 4×H100 (§4, abstract).  Checked at 1 Gbps."""
    out = []
    for model in ("bert-large", "gpt3-24l"):
        a = _estimate(model, FLEETS["50xRTX3080"], "wan_1gbps")
        b = _estimate(model, FLEETS["4xH100"], "wan_1gbps")
        lat_gap = a["latency_s"] / b["latency_s"]
        thr_ratio = a["throughput_samples_s"] / b["throughput_samples_s"]
        out.append({
            "name": f"claims/{model}",
            "latency_gap_3080_over_h100": lat_gap,
            "throughput_ratio_3080_over_h100": thr_ratio,
            "claim_latency_worse": lat_gap > 1.0,
            "claim_throughput_comparable": 0.5 <= thr_ratio <= 2.0,
        })
    return out
