"""Benchmark harness — one function per paper table/figure plus system
microbenchmarks.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only substring]
"""
from __future__ import annotations

import argparse
import sys


def _emit(rows):
    for r in rows:
        name = r.pop("name")
        us = r.pop("us_per_call", "")
        derived = r.pop("derived", "")
        extra = ";".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                         for k, v in r.items())
        blob = ";".join(x for x in [str(derived), extra] if x)
        print(f"{name},{us if us == '' else f'{us:.1f}'},{blob}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import figures, micro

    suites = [
        ("fig4", figures.fig4_partition),
        ("fig5", figures.fig5_bert),
        ("fig6", figures.fig6_gpt3),
        ("table1", figures.table1_cost),
        ("claims", figures.paper_claims_check),
        ("kernels", micro.kernel_bench),
        ("engine", micro.engine_bench),   # includes fleet + prefix sections
        # explicit-only (via --only fleet/prefix): engine_bench already
        # runs them, so a no-filter run must not repeat the workloads
        ("fleet:only", micro.fleet_bench),
        ("prefix:only", micro.prefix_share_bench),
        ("chaos", micro.chaos_bench),     # degraded-mode fault tolerance
        ("migration", micro.migration_bench),  # stateful failover
        ("scheduler", micro.scheduler_bench),
        ("compression", micro.compression_bench),
        ("pipeline", micro.pipeline_bench),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for tag, fn in suites:
        explicit_only = tag.endswith(":only")
        tag = tag.removesuffix(":only")
        if (args.only and args.only not in tag) or \
                (not args.only and explicit_only):
            continue
        try:
            _emit(fn())
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{tag},ERROR,{type(e).__name__}: {e}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
